//! Alg. 3: greedy min-max task assignment (§4.4).
//!
//! Sort selected clients by size descending (LPT order), then place each
//! on the device that minimizes the post-assignment makespan (Eq. 4).
//! Complexity O(K·M_p) — the linear scan over K is kept (K ≤ 32 in every
//! experiment, so the scan beats a heap in practice; `benches/
//! bench_scheduler.rs` measures both claims).
//!
//! `uniform_assign` is the warm-up branch (`r ≤ R_w`) and the
//! "w/o scheduling" ablation: clients split round-robin so device task
//! *counts* are near-equal, sizes ignored.

use super::workload::DeviceEstimate;

/// Warm-up / ablation assignment: round-robin by arbitrary order.
pub fn uniform_assign(clients: &[(usize, usize)], k: usize) -> Vec<Vec<usize>> {
    uniform_assign_masked(clients, &vec![true; k])
}

/// Round-robin over the *alive* device slots only (mid-run device
/// departures leave holes in the slot space; dead slots get nothing).
pub fn uniform_assign_masked(clients: &[(usize, usize)], alive: &[bool]) -> Vec<Vec<usize>> {
    let mut out = vec![Vec::new(); alive.len()];
    let slots: Vec<usize> = (0..alive.len()).filter(|&i| alive[i]).collect();
    if slots.is_empty() {
        return out;
    }
    for (i, (client, _)) in clients.iter().enumerate() {
        out[slots[i % slots.len()]].push(*client);
    }
    out
}

/// Alg. 3 proper. `clients` = (client id, effective samples N_m·E);
/// `est[k]` the fitted per-device model. Returns (assignment, predicted
/// per-device busy seconds).
pub fn greedy_assign(
    clients: &[(usize, usize)],
    est: &[DeviceEstimate],
) -> (Vec<Vec<usize>>, Vec<f64>) {
    let k = est.len();
    greedy_assign_from(clients, est, &vec![true; k], &vec![0.0; k])
}

/// The same greedy min-max step, generalized for mid-round re-planning:
/// only `alive` devices may receive work, and each device starts from
/// `base_load` predicted-busy seconds (its already-committed work).
/// This is what re-places orphaned tasks after a device departure —
/// Alg. 3's placement rule applied to the surviving devices.
pub fn greedy_assign_from(
    clients: &[(usize, usize)],
    est: &[DeviceEstimate],
    alive: &[bool],
    base_load: &[f64],
) -> (Vec<Vec<usize>>, Vec<f64>) {
    greedy_assign_with_cost(clients, est, alive, base_load, &|_, _| 0.0)
}

/// Alg. 3 with an additive placement cost: placing `client` on device
/// `k` costs `est[k].predict(n) + extra(client, k)` seconds.  The hook
/// is how the state-affinity term enters the makespan objective —
/// `extra` is the predicted state-movement time when a client runs
/// away from the worker owning its state
/// ([`SchedulerKind::StateAffinity`](crate::config::SchedulerKind)) —
/// without the greedy core knowing anything about shards.
pub fn greedy_assign_with_cost(
    clients: &[(usize, usize)],
    est: &[DeviceEstimate],
    alive: &[bool],
    base_load: &[f64],
    extra: &dyn Fn(usize, usize) -> f64,
) -> (Vec<Vec<usize>>, Vec<f64>) {
    let k = est.len();
    assert!(k > 0 && alive.len() == k && base_load.len() == k);
    let mut assignment = vec![Vec::new(); k];
    let mut w = base_load.to_vec();
    if !alive.iter().any(|&a| a) {
        return (assignment, w);
    }
    let mut order: Vec<&(usize, usize)> = clients.iter().collect();
    // Descending size; ties by client id for determinism.
    order.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));

    for &&(client, n) in &order {
        // Eq. 4: the device whose updated load minimizes the makespan.
        // Since only w[k*] changes, argmin over k of the resulting
        // max(w[k] + T_{m,k}, max_{j≠k} w[j]) reduces to scanning k.
        let mut best = usize::MAX;
        let mut best_cost = f64::INFINITY;
        for (kk, e) in est.iter().enumerate() {
            if !alive[kk] {
                continue;
            }
            let step = e.predict(n) + extra(client, kk);
            // A degenerate fit (NaN/∞ — OLS on < 2 distinct points fed
            // garbage) must not win the argmin through NaN comparisons;
            // skip it outright so only priceable devices compete.
            if !step.is_finite() {
                continue;
            }
            let new_wk = w[kk] + step;
            // makespan if assigned to kk
            let mut ms = new_wk;
            for (jj, &wj) in w.iter().enumerate() {
                if alive[jj] && jj != kk && wj > ms {
                    ms = wj;
                }
            }
            if ms < best_cost - 1e-15 {
                best_cost = ms;
                best = kk;
            }
        }
        if best == usize::MAX {
            // Every alive device priced this client at NaN/∞: fall back
            // to the least-loaded alive slot so the partition invariant
            // (every client placed exactly once) still holds.
            for kk in 0..k {
                if alive[kk] && (best == usize::MAX || w[kk] < w[best]) {
                    best = kk;
                }
            }
            assignment[best].push(client);
            continue; // the un-priceable step does not inflate w[best]
        }
        w[best] += est[best].predict(n) + extra(client, best);
        assignment[best].push(client);
    }
    (assignment, w)
}

/// Client-id-indexed size table for [`makespan`]: ids index directly
/// into the Vec (selections are dense in practice), so lookups stay
/// deterministic and allocation-light where an unordered map was used
/// before.
pub fn size_table(clients: &[(usize, usize)]) -> Vec<usize> {
    let len = clients.iter().map(|&(c, _)| c + 1).max().unwrap_or(0);
    let mut sizes = vec![0usize; len];
    for &(c, n) in clients {
        sizes[c] = n;
    }
    sizes
}

/// Predicted makespan of an assignment under the given estimates —
/// the objective of Eq. 3 (used by tests and the ablation benches).
/// `sizes` is the client-id-indexed table from [`size_table`].
pub fn makespan(assignment: &[Vec<usize>], sizes: &[usize], est: &[DeviceEstimate]) -> f64 {
    assignment
        .iter()
        .enumerate()
        .map(|(k, tasks)| {
            tasks
                .iter()
                .map(|&c| est[k].predict(sizes[c]))
                .sum::<f64>()
        })
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn homo(k: usize) -> Vec<DeviceEstimate> {
        vec![DeviceEstimate { t_sample: 0.01, b: 0.1, r2: 1.0, n_points: 10 }; k]
    }

    #[test]
    fn all_clients_assigned_exactly_once() {
        let clients: Vec<(usize, usize)> = (0..37).map(|i| (i, 10 + i * 3)).collect();
        let (asg, _) = greedy_assign(&clients, &homo(5));
        let mut seen: Vec<usize> = asg.iter().flatten().cloned().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..37).collect::<Vec<_>>());
    }

    #[test]
    fn balances_homogeneous_loads() {
        // 4 big + 4 small on 2 devices: each device should get 1 big + 1 small-ish mix.
        let clients = vec![(0, 100), (1, 100), (2, 100), (3, 100), (4, 10), (5, 10), (6, 10), (7, 10)];
        let est = homo(2);
        let (asg, w) = greedy_assign(&clients, &est);
        assert!((w[0] - w[1]).abs() < 0.3 * w[0].max(w[1]), "{w:?} {asg:?}");
    }

    #[test]
    fn prefers_fast_device_under_heterogeneity() {
        let est = vec![
            DeviceEstimate { t_sample: 0.01, b: 0.1, r2: 1.0, n_points: 9 }, // fast
            DeviceEstimate { t_sample: 0.04, b: 0.1, r2: 1.0, n_points: 9 }, // 4x slower
        ];
        let clients: Vec<(usize, usize)> = (0..10).map(|i| (i, 100)).collect();
        let (asg, w) = greedy_assign(&clients, &est);
        assert!(asg[0].len() > asg[1].len(), "fast device must take more: {asg:?}");
        // loads should still be balanced in *time*
        assert!((w[0] - w[1]).abs() < 0.5 * w[0].max(w[1]), "{w:?}");
    }

    #[test]
    fn single_device_takes_all() {
        let clients = vec![(0, 5), (1, 50)];
        let (asg, _) = greedy_assign(&clients, &homo(1));
        assert_eq!(asg[0].len(), 2);
    }

    #[test]
    fn empty_round_ok() {
        let (asg, w) = greedy_assign(&[], &homo(3));
        assert!(asg.iter().all(|a| a.is_empty()));
        assert!(w.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn uniform_counts_balanced() {
        let clients: Vec<(usize, usize)> = (0..10).map(|i| (i, 1000 * (i + 1))).collect();
        let asg = uniform_assign(&clients, 4);
        let counts: Vec<usize> = asg.iter().map(|a| a.len()).collect();
        assert_eq!(counts.iter().sum::<usize>(), 10);
        assert!(counts.iter().max().unwrap() - counts.iter().min().unwrap() <= 1);
    }

    #[test]
    fn prop_greedy_never_much_worse_and_dominates_in_aggregate() {
        // Greedy LPT is a heuristic: on adversarial instances a lucky
        // round-robin can beat it by a small margin, so per-instance we
        // only require bounded regression (<= 1.25x); the paper's actual
        // claim (Fig. 7/9: scheduling reduces round time) is checked in
        // aggregate below.
        let mut g_tot = 0.0;
        let mut u_tot = 0.0;
        prop::check("greedy bounded + aggregate win", 60, |g| {
            let k = g.int(1, 8);
            let m = g.int(1, 60);
            let clients: Vec<(usize, usize)> =
                (0..m).map(|i| (i, g.int(2, 500))).collect();
            let est: Vec<DeviceEstimate> = (0..k)
                .map(|_| DeviceEstimate {
                    t_sample: g.f64(0.001, 0.05),
                    b: g.f64(0.0, 0.5),
                    r2: 1.0,
                    n_points: 10,
                })
                .collect();
            let sizes = size_table(&clients);
            let (gasg, _) = greedy_assign(&clients, &est);
            let uasg = uniform_assign(&clients, k);
            let gm = makespan(&gasg, &sizes, &est);
            let um = makespan(&uasg, &sizes, &est);
            g_tot += gm;
            u_tot += um;
            if gm <= 1.25 * um + 1e-9 {
                Ok(())
            } else {
                Err(format!("greedy {gm} >> uniform {um} (k={k}, m={m})"))
            }
        });
        assert!(
            g_tot < 0.85 * u_tot,
            "greedy must win in aggregate: greedy={g_tot:.2} uniform={u_tot:.2}"
        );
    }

    #[test]
    fn prop_within_factor_two_of_lower_bound() {
        // LPT guarantee (homogeneous): makespan <= 2 * LB where
        // LB = max(total/k, max_task).
        prop::check("lpt 2-approx", 60, |g| {
            let k = g.int(1, 8);
            let m = g.int(1, 80);
            let clients: Vec<(usize, usize)> =
                (0..m).map(|i| (i, g.int(2, 400))).collect();
            let est = homo(k);
            let sizes = size_table(&clients);
            let (asg, _) = greedy_assign(&clients, &est);
            let ms = makespan(&asg, &sizes, &est);
            let total: f64 = clients.iter().map(|&(_, n)| est[0].predict(n)).sum();
            let biggest = clients
                .iter()
                .map(|&(_, n)| est[0].predict(n))
                .fold(0.0, f64::max);
            let lb = (total / k as f64).max(biggest);
            if ms <= 2.0 * lb + 1e-9 {
                Ok(())
            } else {
                Err(format!("makespan {ms} > 2*LB {lb}"))
            }
        });
    }

    #[test]
    fn prop_every_client_exactly_once() {
        prop::check("assignment partition", 80, |g| {
            let k = g.int(1, 10);
            let m = g.int(0, 100);
            let clients: Vec<(usize, usize)> =
                (0..m).map(|i| (i, g.int(2, 300))).collect();
            let (asg, _) = greedy_assign(&clients, &homo(k));
            let mut seen: Vec<usize> = asg.iter().flatten().cloned().collect();
            seen.sort_unstable();
            if seen == (0..m).collect::<Vec<_>>() {
                Ok(())
            } else {
                Err(format!("bad partition: {} of {}", seen.len(), m))
            }
        });
    }

    #[test]
    fn masked_uniform_skips_dead_slots() {
        let clients: Vec<(usize, usize)> = (0..6).map(|i| (i, 10)).collect();
        let asg = uniform_assign_masked(&clients, &[true, false, true, false]);
        assert!(asg[1].is_empty() && asg[3].is_empty());
        assert_eq!(asg[0].len() + asg[2].len(), 6);
        // no alive slot: nothing placed, nothing panics
        let none = uniform_assign_masked(&clients, &[false, false]);
        assert!(none.iter().all(|a| a.is_empty()));
    }

    #[test]
    fn masked_greedy_respects_alive_and_base_load() {
        let est = homo(3);
        let clients: Vec<(usize, usize)> = (0..9).map(|i| (i, 100)).collect();
        // device 1 dead; device 0 already committed to 10s of work
        let (asg, w) = greedy_assign_from(&clients, &est, &[true, false, true], &[10.0, 0.0, 0.0]);
        assert!(asg[1].is_empty(), "dead device must get nothing: {asg:?}");
        assert_eq!(asg[0].len() + asg[2].len(), 9);
        // the unloaded device should absorb (nearly) everything
        assert!(asg[2].len() > asg[0].len(), "{asg:?}");
        assert!(w[0] >= 10.0);
    }

    #[test]
    fn masked_greedy_matches_unmasked_when_all_alive() {
        let clients: Vec<(usize, usize)> = (0..23).map(|i| (i, 10 + 7 * i)).collect();
        let est = homo(4);
        let a = greedy_assign(&clients, &est);
        let b = greedy_assign_from(&clients, &est, &[true; 4], &[0.0; 4]);
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
    }

    #[test]
    fn extra_cost_steers_placement_without_breaking_partition() {
        // Affinity-shaped hook: odd clients are "owned" by device 1 —
        // with a dominant penalty for off-owner placement, the greedy
        // step keeps every client home while still assigning each
        // exactly once.
        let est = homo(2);
        let clients: Vec<(usize, usize)> = (0..10).map(|i| (i, 100)).collect();
        let owner = |c: usize| c % 2;
        let extra = |c: usize, k: usize| if owner(c) == k { 0.0 } else { 1e6 };
        let (asg, w) = greedy_assign_with_cost(&clients, &est, &[true, true], &[0.0, 0.0], &extra);
        for (k, list) in asg.iter().enumerate() {
            for &c in list {
                assert_eq!(owner(c), k, "client {c} placed off-owner: {asg:?}");
            }
        }
        let mut seen: Vec<usize> = asg.iter().flatten().cloned().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
        assert!(w[0] < 1e5 && w[1] < 1e5, "no penalty was actually paid: {w:?}");
        // A mild penalty only tilts ties: the makespan objective still
        // dominates, so a huge compute imbalance overrides affinity.
        let lopsided: Vec<(usize, usize)> = vec![(1, 10_000), (3, 10_000), (5, 10_000)];
        let mild = |c: usize, k: usize| if owner(c) == k { 0.0 } else { 0.01 };
        let (asg2, _) =
            greedy_assign_with_cost(&lopsided, &est, &[true, true], &[0.0, 0.0], &mild);
        assert!(
            !asg2[0].is_empty(),
            "makespan balancing must override a mild affinity: {asg2:?}"
        );
    }

    #[test]
    fn zero_extra_cost_matches_plain_greedy() {
        let clients: Vec<(usize, usize)> = (0..23).map(|i| (i, 10 + 7 * i)).collect();
        let est = homo(4);
        let a = greedy_assign_from(&clients, &est, &[true; 4], &[0.0; 4]);
        let b = greedy_assign_with_cost(&clients, &est, &[true; 4], &[0.0; 4], &|_, _| 0.0);
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
    }

    #[test]
    fn degenerate_estimates_never_win_and_never_panic() {
        // Device 0's fit is poisoned (NaN slope → +∞ predictions): it
        // must receive no work, and the healthy device absorbs all of
        // it without the argmin panicking on unset `best`.
        for bad in [f64::NAN, f64::INFINITY] {
            let est = vec![
                DeviceEstimate { t_sample: bad, b: 0.1, r2: 0.0, n_points: 1 },
                DeviceEstimate { t_sample: 0.01, b: 0.1, r2: 1.0, n_points: 9 },
            ];
            let clients: Vec<(usize, usize)> = (0..8).map(|i| (i, 100)).collect();
            let (asg, w) = greedy_assign(&clients, &est);
            assert!(asg[0].is_empty(), "t_sample={bad}: degenerate device won work: {asg:?}");
            assert_eq!(asg[1].len(), 8);
            assert!(w[1].is_finite());
        }
        // Every device degenerate: clients still land somewhere (least-
        // loaded fallback), partition invariant intact, no panic.
        let est = vec![
            DeviceEstimate { t_sample: f64::NAN, b: 0.0, r2: 0.0, n_points: 0 },
            DeviceEstimate { t_sample: f64::INFINITY, b: 0.0, r2: 0.0, n_points: 0 },
        ];
        let clients: Vec<(usize, usize)> = (0..5).map(|i| (i, 50)).collect();
        let (asg, w) = greedy_assign(&clients, &est);
        let mut seen: Vec<usize> = asg.iter().flatten().cloned().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..5).collect::<Vec<_>>());
        assert!(w.iter().all(|x| x.is_finite()), "{w:?}");
        // An ∞ extra-cost hook (unreachable owner) is skipped the same way.
        let est = homo(2);
        let extra = |_c: usize, k: usize| if k == 0 { f64::INFINITY } else { 0.0 };
        let (asg, _) =
            greedy_assign_with_cost(&clients, &est, &[true, true], &[0.0, 0.0], &extra);
        assert!(asg[0].is_empty(), "{asg:?}");
        assert_eq!(asg[1].len(), 5);
    }

    #[test]
    fn deterministic_under_ties() {
        let clients = vec![(3, 50), (1, 50), (2, 50), (0, 50)];
        let a = greedy_assign(&clients, &homo(2)).0;
        let b = greedy_assign(&clients, &homo(2)).0;
        assert_eq!(a, b);
    }
}
