//! Heterogeneity-aware task scheduling (paper §4.3–§4.4, Alg. 3).
//!
//! - [`workload`] — the per-device workload model T_{m,k} = N_m·t_k + b_k
//!   (Eq. 2) fitted by OLS over recorded task runtimes, with either full
//!   history or the Time-Window restriction (§4.4 "Tackling Dynamic
//!   Hardware Environments").
//! - [`greedy`] — Alg. 3's LPT-style min-max assignment: sort clients by
//!   size descending, place each on the device that minimizes the
//!   resulting makespan (Eq. 3–4).
//!
//! The [`Scheduler`] facade ties both to the config's
//! [`SchedulerKind`](crate::config::SchedulerKind) and owns the history.

// Determinism-critical module: re-enable the workspace-wide clippy
// bans on unordered collections and ambient clocks (see clippy.toml
// and the crate-root allow in lib.rs).
#![deny(clippy::disallowed_types, clippy::disallowed_methods)]

pub mod greedy;
pub mod workload;

pub use greedy::{
    greedy_assign, greedy_assign_from, greedy_assign_with_cost, uniform_assign,
    uniform_assign_masked,
};
pub use workload::{DeviceEstimate, History, TaskRecord};

use crate::config::SchedulerKind;
use crate::statestore::ShardMap;

/// State-affinity context
/// ([`SchedulerKind::StateAffinity`](crate::config::SchedulerKind)):
/// who owns each client's state, and what moving that state costs.
/// Placing a client on a worker other than its owner adds
/// `remote_secs × weight` to the greedy objective — the scheduler
/// trades makespan balance against state movement instead of ignoring
/// it.
#[derive(Debug, Clone)]
pub struct AffinityCtx {
    pub map: ShardMap,
    pub n_workers: usize,
    /// Predicted seconds to move one client state off-owner (fetch +
    /// write-back return over the coordinator transport).
    pub remote_secs: f64,
}

impl AffinityCtx {
    /// The worker hosting `client`'s state (shard s lives on worker s).
    pub fn owner_worker(&self, client: usize) -> usize {
        self.map.owner(client as u64) as usize % self.n_workers.max(1)
    }
}

/// Outcome of scheduling one round.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Per-device client-index lists: `assignment[k]` = clients for device k.
    pub assignment: Vec<Vec<usize>>,
    /// Predicted per-device busy time (seconds) under the fitted model.
    pub predicted: Vec<f64>,
    /// Wallclock cost of estimation + assignment (Fig. 8's metric).
    pub overhead_secs: f64,
    /// Whether the fitted model (vs the warm-up uniform split) was used.
    pub used_model: bool,
    /// The per-device estimates the greedy pass used (None in the
    /// uniform/warm-up branch) — exposed so callers computing
    /// prediction error don't re-fit the whole history.
    pub estimates: Option<Vec<DeviceEstimate>>,
}

/// Stateful scheduler: owns the runtime history and applies Alg. 3.
pub struct Scheduler {
    pub kind: SchedulerKind,
    pub warmup_rounds: usize,
    pub history: History,
    n_devices: usize,
    /// Ownership ring + movement cost behind the state-affinity term;
    /// None (or a non-affinity `kind`) degrades to plain Alg. 3.
    affinity: Option<AffinityCtx>,
    /// Injected wallclock for `overhead_secs` accounting (Fig. 8).
    /// None — the deterministic default — reports 0.0: the scheduler
    /// itself never reads ambient time, so same-seed runs stay
    /// byte-identical; deploy-side callers that consume the overhead
    /// metric inject `util::timer::wall_secs`.
    clock: Option<fn() -> f64>,
}

impl Scheduler {
    pub fn new(kind: SchedulerKind, warmup_rounds: usize, n_devices: usize) -> Scheduler {
        Scheduler {
            kind,
            warmup_rounds,
            history: History::new(),
            n_devices,
            affinity: None,
            clock: None,
        }
    }

    /// Attach (or clear) the state-affinity context.  The term only
    /// bites when `kind` is [`SchedulerKind::StateAffinity`].
    pub fn set_affinity(&mut self, ctx: Option<AffinityCtx>) {
        self.affinity = ctx;
    }

    /// Inject a wallclock for `overhead_secs` accounting.  Without
    /// one, scheduling overhead reports as 0.0.
    pub fn set_wall_clock(&mut self, clock: fn() -> f64) {
        self.clock = Some(clock);
    }

    fn now(&self) -> Option<f64> {
        self.clock.map(|c| c())
    }

    fn overhead_since(&self, t0: Option<f64>) -> f64 {
        match (self.clock, t0) {
            (Some(c), Some(t0)) => (c() - t0).max(0.0),
            _ => 0.0,
        }
    }

    /// Off-owner placement penalty in seconds (0 when affinity is off).
    fn affinity_penalty(&self) -> f64 {
        match (self.kind, &self.affinity) {
            (SchedulerKind::StateAffinity { weight_pct, .. }, Some(ctx)) => {
                ctx.remote_secs * weight_pct as f64 / 100.0
            }
            _ => 0.0,
        }
    }

    /// Record a finished task (device k ran `n_eff` effective samples in
    /// `secs` at round r) — what devices piggyback on their result
    /// messages (§4.3 Estimation).
    pub fn record(&mut self, rec: TaskRecord) {
        self.history.push(rec);
    }

    /// Schedule `clients` = (client id, effective samples N_m·E) for round `r`.
    pub fn schedule(&mut self, round: usize, clients: &[(usize, usize)]) -> Schedule {
        let alive = vec![true; self.n_devices];
        self.schedule_masked(round, clients, &alive)
    }

    /// [`Scheduler::schedule`] restricted to the `alive` device slots —
    /// the entry point when the cluster has lost (or not yet regained)
    /// devices.  Dead slots receive no work and contribute nothing to
    /// the makespan objective.
    pub fn schedule_masked(
        &mut self,
        round: usize,
        clients: &[(usize, usize)],
        alive: &[bool],
    ) -> Schedule {
        let zero = vec![0.0; self.n_devices];
        self.schedule_from(round, clients, alive, &zero)
    }

    /// [`Scheduler::schedule_masked`] generalized for mid-stream
    /// re-planning: each device starts from `base_load` already-
    /// committed seconds.  With an all-zero base this is exactly
    /// `schedule_masked` — the async dispatcher admits a cohort against
    /// the executors' current projected loads through this entry point,
    /// applying Alg. 3's placement rule incrementally instead of from a
    /// round barrier.  (The uniform/warm-up branch ignores the base: it
    /// has no load objective to weigh it against.)
    pub fn schedule_from(
        &mut self,
        round: usize,
        clients: &[(usize, usize)],
        alive: &[bool],
        base_load: &[f64],
    ) -> Schedule {
        assert_eq!(alive.len(), self.n_devices, "alive mask length");
        assert_eq!(base_load.len(), self.n_devices, "base load length");
        let t0 = self.now();
        let uniform_only = matches!(self.kind, SchedulerKind::Uniform);
        let in_warmup = round < self.warmup_rounds;
        if uniform_only || in_warmup {
            let assignment = uniform_assign_masked(clients, alive);
            let predicted = vec![0.0; self.n_devices];
            return Schedule {
                assignment,
                predicted,
                overhead_secs: self.overhead_since(t0),
                used_model: false,
                estimates: None,
            };
        }
        // Time-Window kinds never look behind round − τ again, so the
        // stale records can go — this is also what bounds history memory
        // on long runs.  saturating_sub: scheduling at round < τ must
        // not underflow (and prunes nothing).
        if let Some(w) = self.window() {
            self.history.prune(round.saturating_sub(w));
        }
        let window = self.window();
        let estimates = self.history.estimate(self.n_devices, round, window);
        let penalty = self.affinity_penalty();
        let (assignment, predicted) = if penalty > 0.0 {
            let ctx = self.affinity.as_ref().expect("penalty > 0 implies ctx");
            let extra = |client: usize, dev: usize| {
                if ctx.owner_worker(client) == dev {
                    0.0
                } else {
                    penalty
                }
            };
            greedy_assign_with_cost(clients, &estimates, alive, base_load, &extra)
        } else {
            greedy_assign_from(clients, &estimates, alive, base_load)
        };
        Schedule {
            assignment,
            predicted,
            overhead_secs: self.overhead_since(t0),
            used_model: true,
            estimates: Some(estimates),
        }
    }

    /// Two-stage placement over a grouped topology (multi-level
    /// hierarchy, `--topology groups:G | tree:SPEC`): clients go first
    /// to a *group* (by state affinity + load through the Alg. 3 cost
    /// hook, each group priced as the parallel service rate of its
    /// alive members), then to a device *within* that group by the
    /// plain greedy min-max step.  `groups[g]` lists group g's device
    /// slots.  Warm-up / uniform rounds fall back to the flat
    /// round-robin split (group-agnostic, like `schedule_masked`).
    pub fn schedule_grouped(
        &mut self,
        round: usize,
        clients: &[(usize, usize)],
        alive: &[bool],
        groups: &[Vec<usize>],
    ) -> Schedule {
        let zero = vec![0.0; self.n_devices];
        self.schedule_grouped_from(round, clients, alive, &zero, groups)
    }

    /// [`Scheduler::schedule_grouped`] generalized for mid-stream
    /// re-planning (the async dispatcher's incremental admissions):
    /// each device starts from `base_load` committed seconds.
    pub fn schedule_grouped_from(
        &mut self,
        round: usize,
        clients: &[(usize, usize)],
        alive: &[bool],
        base_load: &[f64],
        groups: &[Vec<usize>],
    ) -> Schedule {
        assert_eq!(alive.len(), self.n_devices, "alive mask length");
        assert_eq!(base_load.len(), self.n_devices, "base load length");
        assert!(!groups.is_empty(), "schedule_grouped needs at least one group");
        let t0 = self.now();
        let uniform_only = matches!(self.kind, SchedulerKind::Uniform);
        if uniform_only || round < self.warmup_rounds {
            let assignment = uniform_assign_masked(clients, alive);
            return Schedule {
                assignment,
                predicted: vec![0.0; self.n_devices],
                overhead_secs: self.overhead_since(t0),
                used_model: false,
                estimates: None,
            };
        }
        if let Some(w) = self.window() {
            self.history.prune(round.saturating_sub(w));
        }
        let window = self.window();
        let estimates = self.history.estimate(self.n_devices, round, window);

        // --- stage 1: client -> group -------------------------------
        // A group's service model: parallel rate of its alive members
        // (t_sample = 1/Σ 1/t_k, b = mean b_k); its head start = the
        // mean committed load per member.  Dead/unpriceable groups
        // price at +∞ and never win (the greedy NaN/∞ guard).
        let mut device_group = vec![usize::MAX; self.n_devices];
        for (g, members) in groups.iter().enumerate() {
            for &d in members {
                if d < self.n_devices {
                    device_group[d] = g;
                }
            }
        }
        let mut gests = Vec::with_capacity(groups.len());
        let mut galive = Vec::with_capacity(groups.len());
        let mut gbase = Vec::with_capacity(groups.len());
        for members in groups {
            let mut rate = 0.0f64;
            let mut b_sum = 0.0f64;
            let mut n = 0usize;
            let mut base_sum = 0.0f64;
            for &d in members {
                if d < self.n_devices && alive[d] {
                    let e = &estimates[d];
                    if e.t_sample.is_finite() && e.t_sample > 0.0 {
                        rate += 1.0 / e.t_sample;
                    }
                    if e.b.is_finite() {
                        b_sum += e.b;
                    }
                    base_sum += base_load[d];
                    n += 1;
                }
            }
            let ok = n > 0 && rate > 0.0;
            galive.push(ok);
            gbase.push(if n > 0 { base_sum / n as f64 } else { 0.0 });
            gests.push(if ok {
                DeviceEstimate { t_sample: 1.0 / rate, b: b_sum / n as f64, r2: 1.0, n_points: n }
            } else {
                DeviceEstimate { t_sample: f64::INFINITY, b: 0.0, r2: 0.0, n_points: 0 }
            });
        }
        // Every group unpriceable (degenerate fits on every alive
        // device): degrade to the flat greedy step, whose least-loaded
        // fallback keeps the every-client-placed-exactly-once
        // invariant — matching the flat scheduler's behavior instead of
        // silently scheduling nothing.
        if !galive.iter().any(|&a| a) {
            let (assignment, predicted) =
                greedy_assign_from(clients, &estimates, alive, base_load);
            return Schedule {
                assignment,
                predicted,
                overhead_secs: self.overhead_since(t0),
                used_model: true,
                estimates: Some(estimates),
            };
        }
        let penalty = self.affinity_penalty();
        let (group_assign, _) = if penalty > 0.0 {
            let ctx = self.affinity.as_ref().expect("penalty > 0 implies ctx");
            let extra = |client: usize, g: usize| {
                let owner = ctx.owner_worker(client);
                if device_group.get(owner).copied() == Some(g) {
                    0.0
                } else {
                    penalty
                }
            };
            greedy_assign_with_cost(clients, &gests, &galive, &gbase, &extra)
        } else {
            greedy_assign_from(clients, &gests, &galive, &gbase)
        };

        // --- stage 2: client -> device within the group -------------
        let size_of = greedy::size_table(clients);
        let mut assignment = vec![Vec::new(); self.n_devices];
        let mut predicted = base_load.to_vec();
        for (g, members) in groups.iter().enumerate() {
            if group_assign[g].is_empty() {
                continue;
            }
            let sub: Vec<(usize, usize)> =
                group_assign[g].iter().map(|&c| (c, size_of[c])).collect();
            let sub_est: Vec<DeviceEstimate> =
                members.iter().map(|&d| estimates[d]).collect();
            let sub_alive: Vec<bool> = members.iter().map(|&d| alive[d]).collect();
            let sub_base: Vec<f64> = members.iter().map(|&d| base_load[d]).collect();
            let (sub_assign, sub_w) =
                greedy_assign_from(&sub, &sub_est, &sub_alive, &sub_base);
            for (local, &d) in members.iter().enumerate() {
                assignment[d].extend(sub_assign[local].iter().cloned());
                predicted[d] = sub_w[local];
            }
        }
        Schedule {
            assignment,
            predicted,
            overhead_secs: self.overhead_since(t0),
            used_model: true,
            estimates: Some(estimates),
        }
    }

    /// Re-place tasks orphaned by a mid-round device departure: the
    /// same greedy min-max step (Eq. 4) over the surviving devices,
    /// starting from each survivor's already-committed `base_load`
    /// predicted seconds.  Returns per-device lists of the orphaned
    /// ids (the caller's task/client handles).
    ///
    /// Deliberately affinity-free: the handles here are the caller's
    /// opaque task ids (not client ids), and a departure hands the
    /// dead worker's shard off anyway, so plan-time ownership is
    /// already stale by the time orphans move.
    pub fn reassign_orphans(
        &mut self,
        round: usize,
        orphans: &[(usize, usize)],
        alive: &[bool],
        base_load: &[f64],
    ) -> Vec<Vec<usize>> {
        if orphans.is_empty() || !alive.iter().any(|&a| a) {
            return vec![Vec::new(); self.n_devices];
        }
        let window = self.window();
        let estimates = self.history.estimate(self.n_devices, round, window);
        greedy_assign_from(orphans, &estimates, alive, base_load).0
    }

    /// Forget a departed device's runtime records (its slot may later
    /// host different hardware — see [`History::prune_device`]).
    pub fn prune_device(&mut self, device: usize) {
        self.history.prune_device(device);
    }

    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    fn window(&self) -> Option<usize> {
        match self.kind {
            SchedulerKind::TimeWindow(t) => Some(t),
            SchedulerKind::StateAffinity { window, .. } if window > 0 => Some(window),
            _ => None,
        }
    }

    /// Current per-device estimates (Fig. 6 visualization).
    pub fn estimates(&self, round: usize) -> Vec<DeviceEstimate> {
        self.history.estimate(self.n_devices, round, self.window())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn clients(sizes: &[usize]) -> Vec<(usize, usize)> {
        sizes.iter().cloned().enumerate().collect()
    }

    #[test]
    fn warmup_uses_uniform() {
        let mut s = Scheduler::new(SchedulerKind::Greedy, 2, 4);
        let sch = s.schedule(0, &clients(&[50, 40, 30, 20, 10, 5, 4, 3]));
        assert!(!sch.used_model);
        assert_eq!(sch.assignment.len(), 4);
        let total: usize = sch.assignment.iter().map(|a| a.len()).sum();
        assert_eq!(total, 8);
    }

    #[test]
    fn after_warmup_uses_model() {
        let mut s = Scheduler::new(SchedulerKind::Greedy, 1, 2);
        // Seed history: device 0 twice as fast.
        for r in 0..3 {
            for (n, d, t) in [(100, 0, 1.0), (200, 0, 2.0), (100, 1, 2.0), (200, 1, 4.0)] {
                s.record(TaskRecord { round: r, device: d, n_samples: n, secs: t });
            }
        }
        let sch = s.schedule(3, &clients(&[100, 100, 100]));
        assert!(sch.used_model);
        // Fast device should get more work.
        assert!(sch.assignment[0].len() >= sch.assignment[1].len());
        let total: usize = sch.assignment.iter().map(|a| a.len()).sum();
        assert_eq!(total, 3);
    }

    #[test]
    fn uniform_kind_never_models() {
        let mut s = Scheduler::new(SchedulerKind::Uniform, 0, 2);
        for r in 0..5 {
            s.record(TaskRecord { round: r, device: 0, n_samples: 10, secs: 1.0 });
        }
        assert!(!s.schedule(10, &clients(&[1, 2, 3])).used_model);
    }

    #[test]
    fn masked_schedule_avoids_dead_devices() {
        let mut s = Scheduler::new(SchedulerKind::Greedy, 0, 3);
        for r in 0..3 {
            for d in 0..3 {
                s.record(TaskRecord { round: r, device: d, n_samples: 100, secs: 1.0 });
                s.record(TaskRecord { round: r, device: d, n_samples: 200, secs: 2.0 });
            }
        }
        let sch = s.schedule_masked(3, &clients(&[50, 40, 30, 20]), &[true, false, true]);
        assert!(sch.used_model);
        assert!(sch.assignment[1].is_empty(), "{:?}", sch.assignment);
        let total: usize = sch.assignment.iter().map(|a| a.len()).sum();
        assert_eq!(total, 4);
        // uniform branch honors the mask too
        let mut u = Scheduler::new(SchedulerKind::Uniform, 0, 3);
        let sch = u.schedule_masked(0, &clients(&[50, 40, 30, 20]), &[false, true, true]);
        assert!(sch.assignment[0].is_empty());
    }

    #[test]
    fn reassign_orphans_prefers_lightly_loaded_survivors() {
        let mut s = Scheduler::new(SchedulerKind::Greedy, 0, 3);
        for r in 0..2 {
            for d in 0..3 {
                s.record(TaskRecord { round: r, device: d, n_samples: 100, secs: 1.0 });
                s.record(TaskRecord { round: r, device: d, n_samples: 300, secs: 3.0 });
            }
        }
        // device 0 departed; device 1 is nearly free, device 2 is loaded
        let placed = s.reassign_orphans(
            2,
            &[(7, 100), (8, 100), (9, 100)],
            &[false, true, true],
            &[0.0, 0.5, 30.0],
        );
        assert!(placed[0].is_empty(), "{placed:?}");
        assert_eq!(placed.iter().map(|p| p.len()).sum::<usize>(), 3);
        assert!(placed[1].len() >= placed[2].len(), "{placed:?}");
        // no survivors -> nothing placed (caller drops the tasks)
        let none = s.reassign_orphans(2, &[(1, 10)], &[false, false, false], &[0.0, 0.0, 0.0]);
        assert!(none.iter().all(|p| p.is_empty()));
    }

    #[test]
    fn prune_device_forgets_history() {
        let mut s = Scheduler::new(SchedulerKind::Greedy, 0, 2);
        s.record(TaskRecord { round: 0, device: 0, n_samples: 10, secs: 1.0 });
        s.record(TaskRecord { round: 0, device: 1, n_samples: 10, secs: 1.0 });
        s.prune_device(0);
        assert_eq!(s.history.len(), 1);
        assert!(s.history.records().iter().all(|r| r.device == 1));
    }

    #[test]
    fn state_affinity_prefers_owner_workers() {
        use crate::statestore::ShardMap;
        let map = ShardMap::new(3);
        let mk = |kind| {
            let mut s = Scheduler::new(kind, 0, 3);
            for r in 0..3 {
                for d in 0..3 {
                    s.record(TaskRecord { round: r, device: d, n_samples: 100, secs: 1.0 });
                    s.record(TaskRecord { round: r, device: d, n_samples: 200, secs: 2.0 });
                }
            }
            s.set_affinity(Some(AffinityCtx {
                map: map.clone(),
                n_workers: 3,
                remote_secs: 1e5, // dwarfs any compute imbalance
            }));
            s
        };
        let cs = clients(&[100, 100, 100, 100, 100, 100, 100, 100, 100]);
        let mut aff = mk(SchedulerKind::StateAffinity { window: 0, weight_pct: 100 });
        let sch = aff.schedule(3, &cs);
        assert!(sch.used_model);
        for (dev, list) in sch.assignment.iter().enumerate() {
            for &c in list {
                assert_eq!(
                    map.owner(c as u64) as usize,
                    dev,
                    "client {c} scheduled off-owner: {:?}",
                    sch.assignment
                );
            }
        }
        // Same context on a plain Greedy kind: the term must not bite.
        let mut plain = mk(SchedulerKind::Greedy);
        let sp = plain.schedule(3, &cs);
        let spread = |a: &[Vec<usize>]| a.iter().map(|l| l.len()).max().unwrap();
        assert!(spread(&sp.assignment) <= 4, "greedy stays balanced: {:?}", sp.assignment);
        // Affinity with zero weight degrades to plain greedy too.
        let mut zero = mk(SchedulerKind::StateAffinity { window: 0, weight_pct: 0 });
        assert_eq!(zero.schedule(3, &cs).assignment, sp.assignment);
        // The windowed variant threads its window through estimation.
        let w = Scheduler::new(SchedulerKind::StateAffinity { window: 4, weight_pct: 50 }, 0, 3);
        assert_eq!(w.window(), Some(4));
    }

    #[test]
    fn grouped_schedule_partitions_and_balances_across_groups() {
        let mut s = Scheduler::new(SchedulerKind::Greedy, 0, 4);
        for r in 0..3 {
            for d in 0..4 {
                s.record(TaskRecord { round: r, device: d, n_samples: 100, secs: 1.0 });
                s.record(TaskRecord { round: r, device: d, n_samples: 200, secs: 2.0 });
            }
        }
        let groups = vec![vec![0, 2], vec![1, 3]];
        let cs = clients(&[90, 80, 70, 60, 50, 40, 30, 20]);
        let sch = s.schedule_grouped(3, &cs, &[true; 4], &groups);
        assert!(sch.used_model);
        // Every client placed exactly once.
        let mut seen: Vec<usize> = sch.assignment.iter().flatten().cloned().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..8).collect::<Vec<_>>());
        // Homogeneous equal groups: the split must not be lopsided.
        let g0: usize = sch.assignment[0].len() + sch.assignment[2].len();
        let g1: usize = sch.assignment[1].len() + sch.assignment[3].len();
        assert!(g0 >= 2 && g1 >= 2, "groups {g0}/{g1}: {:?}", sch.assignment);
        // Warm-up falls back to the flat uniform split.
        let mut w = Scheduler::new(SchedulerKind::Greedy, 5, 4);
        let sw = w.schedule_grouped(0, &cs, &[true; 4], &groups);
        assert!(!sw.used_model);
        assert_eq!(sw.assignment.iter().map(|a| a.len()).sum::<usize>(), 8);
    }

    #[test]
    fn grouped_schedule_respects_dead_groups_and_member_masks() {
        let mut s = Scheduler::new(SchedulerKind::Greedy, 0, 4);
        for r in 0..3 {
            for d in 0..4 {
                s.record(TaskRecord { round: r, device: d, n_samples: 100, secs: 1.0 });
                s.record(TaskRecord { round: r, device: d, n_samples: 200, secs: 2.0 });
            }
        }
        let groups = vec![vec![0, 2], vec![1, 3]];
        let cs = clients(&[90, 80, 70, 60]);
        // Group 1 entirely dead: everything lands on group 0's members.
        let sch = s.schedule_grouped(3, &cs, &[true, false, true, false], &groups);
        assert!(sch.assignment[1].is_empty() && sch.assignment[3].is_empty());
        assert_eq!(sch.assignment[0].len() + sch.assignment[2].len(), 4);
        // One dead member inside a group: its slot stays empty.
        let sch2 = s.schedule_grouped(3, &cs, &[true, true, false, true], &groups);
        assert!(sch2.assignment[2].is_empty(), "{:?}", sch2.assignment);
        let total: usize = sch2.assignment.iter().map(|a| a.len()).sum();
        assert_eq!(total, 4);
    }

    #[test]
    fn grouped_schedule_with_all_degenerate_fits_still_places_everyone() {
        // Poisoned history (NaN runtimes) makes every device estimate
        // non-finite, so no group can be priced — the grouped path must
        // degrade to the flat greedy step's least-loaded fallback, not
        // silently schedule nothing.
        let mut s = Scheduler::new(SchedulerKind::Greedy, 0, 4);
        for d in 0..4 {
            s.record(TaskRecord { round: 0, device: d, n_samples: 100, secs: f64::NAN });
            s.record(TaskRecord { round: 0, device: d, n_samples: 200, secs: f64::NAN });
        }
        let groups = vec![vec![0, 2], vec![1, 3]];
        let cs = clients(&[90, 80, 70, 60, 50]);
        let sch = s.schedule_grouped(1, &cs, &[true; 4], &groups);
        let mut seen: Vec<usize> = sch.assignment.iter().flatten().cloned().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..5).collect::<Vec<_>>(), "{:?}", sch.assignment);
        assert!(sch.predicted.iter().all(|p| p.is_finite()), "{:?}", sch.predicted);
    }

    #[test]
    fn grouped_schedule_prefers_fast_groups_and_owner_groups() {
        use crate::statestore::ShardMap;
        // Devices 0,2 (group 0) are 4x faster than 1,3 (group 1).
        let mk = |kind| {
            let mut s = Scheduler::new(kind, 0, 4);
            for r in 0..3 {
                for d in 0..4 {
                    let slow = if d % 2 == 0 { 1.0 } else { 4.0 };
                    s.record(TaskRecord { round: r, device: d, n_samples: 100, secs: slow });
                    s.record(TaskRecord {
                        round: r,
                        device: d,
                        n_samples: 200,
                        secs: 2.0 * slow,
                    });
                }
            }
            s
        };
        let groups = vec![vec![0, 2], vec![1, 3]];
        let cs = clients(&[100; 12]);
        let mut s = mk(SchedulerKind::Greedy);
        let sch = s.schedule_grouped(3, &cs, &[true; 4], &groups);
        let g0: usize = sch.assignment[0].len() + sch.assignment[2].len();
        let g1: usize = sch.assignment[1].len() + sch.assignment[3].len();
        assert!(g0 > g1, "fast group must absorb more: {g0} vs {g1}");
        // A dominant affinity pulls every client to its owner's group.
        let map = ShardMap::new(4);
        let mut aff = mk(SchedulerKind::StateAffinity { window: 0, weight_pct: 100 });
        aff.set_affinity(Some(AffinityCtx {
            map: map.clone(),
            n_workers: 4,
            remote_secs: 1e5,
        }));
        let sch = aff.schedule_grouped(3, &cs, &[true; 4], &groups);
        for (dev, list) in sch.assignment.iter().enumerate() {
            for &c in list {
                let owner = map.owner(c as u64) as usize % 4;
                let owner_group = owner % 2; // groups split even/odd slots
                assert_eq!(
                    dev % 2,
                    owner_group,
                    "client {c} (owner {owner}) landed outside the owner's group: {:?}",
                    sch.assignment
                );
            }
        }
        // Zero-weight affinity degrades to plain grouped greedy.
        let mut zero = mk(SchedulerKind::StateAffinity { window: 0, weight_pct: 0 });
        zero.set_affinity(Some(AffinityCtx { map, n_workers: 4, remote_secs: 1e5 }));
        let mut plain = mk(SchedulerKind::Greedy);
        assert_eq!(
            zero.schedule_grouped(3, &cs, &[true; 4], &groups).assignment,
            plain.schedule_grouped(3, &cs, &[true; 4], &groups).assignment
        );
    }

    #[test]
    fn window_prune_bounds_history_and_survives_early_rounds() {
        let mut s = Scheduler::new(SchedulerKind::TimeWindow(3), 0, 2);
        for r in 0..10 {
            for d in 0..2 {
                s.record(TaskRecord { round: r, device: d, n_samples: 100, secs: 1.0 });
                s.record(TaskRecord { round: r, device: d, n_samples: 200, secs: 2.0 });
            }
        }
        // round < window: saturating_sub keeps everything, no underflow.
        let sch = s.schedule(2, &clients(&[50, 40]));
        assert!(sch.used_model);
        assert_eq!(s.history.len(), 40, "nothing pruned before the window fills");
        // Past the window, records older than round − τ are dropped —
        // exactly the set the windowed estimate would never read again.
        s.schedule(10, &clients(&[50, 40]));
        assert!(s.history.records().iter().all(|r| r.round >= 7), "{:?}", s.history.len());
        assert_eq!(s.history.len(), 3 * 2 * 2);
        // Un-windowed kinds keep full history.
        let mut g = Scheduler::new(SchedulerKind::Greedy, 0, 2);
        for r in 0..10 {
            g.record(TaskRecord { round: r, device: 0, n_samples: 100, secs: 1.0 });
            g.record(TaskRecord { round: r, device: 1, n_samples: 200, secs: 2.0 });
        }
        g.schedule(10, &clients(&[50, 40]));
        assert_eq!(g.history.len(), 20);
    }

    #[test]
    fn schedule_from_zero_base_matches_schedule_masked() {
        let seed_records = |s: &mut Scheduler| {
            for r in 0..3 {
                for d in 0..3 {
                    s.record(TaskRecord { round: r, device: d, n_samples: 100, secs: 1.0 });
                    s.record(TaskRecord { round: r, device: d, n_samples: 200, secs: 2.0 });
                }
            }
        };
        let cs = clients(&[90, 70, 50, 30, 20, 10]);
        let mut a = Scheduler::new(SchedulerKind::Greedy, 0, 3);
        let mut b = Scheduler::new(SchedulerKind::Greedy, 0, 3);
        seed_records(&mut a);
        seed_records(&mut b);
        let alive = [true, true, true];
        let sa = a.schedule_masked(3, &cs, &alive);
        let sb = b.schedule_from(3, &cs, &alive, &[0.0, 0.0, 0.0]);
        assert_eq!(sa.assignment, sb.assignment);
        assert_eq!(sa.predicted, sb.predicted);
        // A loaded device receives less incremental work.
        let mut c = Scheduler::new(SchedulerKind::Greedy, 0, 3);
        seed_records(&mut c);
        let sc = c.schedule_from(3, &cs, &alive, &[100.0, 0.0, 0.0]);
        assert!(
            sc.assignment[0].len() <= sb.assignment[0].len(),
            "{:?} vs {:?}",
            sc.assignment,
            sb.assignment
        );
        assert!(sc.assignment[0].is_empty(), "100s head start dwarfs this cohort");
    }

    #[test]
    fn overhead_is_measured() {
        let mut s = Scheduler::new(SchedulerKind::Greedy, 0, 8);
        for r in 0..3 {
            for k in 0..8 {
                s.record(TaskRecord { round: r, device: k, n_samples: 100, secs: 1.0 });
                s.record(TaskRecord { round: r, device: k, n_samples: 200, secs: 1.9 });
            }
        }
        let sch = s.schedule(5, &clients(&(1..200).collect::<Vec<_>>()));
        assert!(sch.overhead_secs >= 0.0);
        assert!(sch.used_model);
    }
}
