//! Heterogeneity-aware task scheduling (paper §4.3–§4.4, Alg. 3).
//!
//! - [`workload`] — the per-device workload model T_{m,k} = N_m·t_k + b_k
//!   (Eq. 2) fitted by OLS over recorded task runtimes, with either full
//!   history or the Time-Window restriction (§4.4 "Tackling Dynamic
//!   Hardware Environments").
//! - [`greedy`] — Alg. 3's LPT-style min-max assignment: sort clients by
//!   size descending, place each on the device that minimizes the
//!   resulting makespan (Eq. 3–4).
//!
//! The [`Scheduler`] facade ties both to the config's
//! [`SchedulerKind`](crate::config::SchedulerKind) and owns the history.

pub mod greedy;
pub mod workload;

pub use greedy::{
    greedy_assign, greedy_assign_from, greedy_assign_with_cost, uniform_assign,
    uniform_assign_masked,
};
pub use workload::{DeviceEstimate, History, TaskRecord};

use crate::config::SchedulerKind;
use crate::statestore::ShardMap;

/// State-affinity context
/// ([`SchedulerKind::StateAffinity`](crate::config::SchedulerKind)):
/// who owns each client's state, and what moving that state costs.
/// Placing a client on a worker other than its owner adds
/// `remote_secs × weight` to the greedy objective — the scheduler
/// trades makespan balance against state movement instead of ignoring
/// it.
#[derive(Debug, Clone)]
pub struct AffinityCtx {
    pub map: ShardMap,
    pub n_workers: usize,
    /// Predicted seconds to move one client state off-owner (fetch +
    /// write-back return over the coordinator transport).
    pub remote_secs: f64,
}

impl AffinityCtx {
    /// The worker hosting `client`'s state (shard s lives on worker s).
    pub fn owner_worker(&self, client: usize) -> usize {
        self.map.owner(client as u64) as usize % self.n_workers.max(1)
    }
}

/// Outcome of scheduling one round.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Per-device client-index lists: `assignment[k]` = clients for device k.
    pub assignment: Vec<Vec<usize>>,
    /// Predicted per-device busy time (seconds) under the fitted model.
    pub predicted: Vec<f64>,
    /// Wallclock cost of estimation + assignment (Fig. 8's metric).
    pub overhead_secs: f64,
    /// Whether the fitted model (vs the warm-up uniform split) was used.
    pub used_model: bool,
    /// The per-device estimates the greedy pass used (None in the
    /// uniform/warm-up branch) — exposed so callers computing
    /// prediction error don't re-fit the whole history.
    pub estimates: Option<Vec<DeviceEstimate>>,
}

/// Stateful scheduler: owns the runtime history and applies Alg. 3.
pub struct Scheduler {
    pub kind: SchedulerKind,
    pub warmup_rounds: usize,
    pub history: History,
    n_devices: usize,
    /// Ownership ring + movement cost behind the state-affinity term;
    /// None (or a non-affinity `kind`) degrades to plain Alg. 3.
    affinity: Option<AffinityCtx>,
}

impl Scheduler {
    pub fn new(kind: SchedulerKind, warmup_rounds: usize, n_devices: usize) -> Scheduler {
        Scheduler { kind, warmup_rounds, history: History::new(), n_devices, affinity: None }
    }

    /// Attach (or clear) the state-affinity context.  The term only
    /// bites when `kind` is [`SchedulerKind::StateAffinity`].
    pub fn set_affinity(&mut self, ctx: Option<AffinityCtx>) {
        self.affinity = ctx;
    }

    /// Off-owner placement penalty in seconds (0 when affinity is off).
    fn affinity_penalty(&self) -> f64 {
        match (self.kind, &self.affinity) {
            (SchedulerKind::StateAffinity { weight_pct, .. }, Some(ctx)) => {
                ctx.remote_secs * weight_pct as f64 / 100.0
            }
            _ => 0.0,
        }
    }

    /// Record a finished task (device k ran `n_eff` effective samples in
    /// `secs` at round r) — what devices piggyback on their result
    /// messages (§4.3 Estimation).
    pub fn record(&mut self, rec: TaskRecord) {
        self.history.push(rec);
    }

    /// Schedule `clients` = (client id, effective samples N_m·E) for round `r`.
    pub fn schedule(&mut self, round: usize, clients: &[(usize, usize)]) -> Schedule {
        let alive = vec![true; self.n_devices];
        self.schedule_masked(round, clients, &alive)
    }

    /// [`Scheduler::schedule`] restricted to the `alive` device slots —
    /// the entry point when the cluster has lost (or not yet regained)
    /// devices.  Dead slots receive no work and contribute nothing to
    /// the makespan objective.
    pub fn schedule_masked(
        &mut self,
        round: usize,
        clients: &[(usize, usize)],
        alive: &[bool],
    ) -> Schedule {
        let zero = vec![0.0; self.n_devices];
        self.schedule_from(round, clients, alive, &zero)
    }

    /// [`Scheduler::schedule_masked`] generalized for mid-stream
    /// re-planning: each device starts from `base_load` already-
    /// committed seconds.  With an all-zero base this is exactly
    /// `schedule_masked` — the async dispatcher admits a cohort against
    /// the executors' current projected loads through this entry point,
    /// applying Alg. 3's placement rule incrementally instead of from a
    /// round barrier.  (The uniform/warm-up branch ignores the base: it
    /// has no load objective to weigh it against.)
    pub fn schedule_from(
        &mut self,
        round: usize,
        clients: &[(usize, usize)],
        alive: &[bool],
        base_load: &[f64],
    ) -> Schedule {
        assert_eq!(alive.len(), self.n_devices, "alive mask length");
        assert_eq!(base_load.len(), self.n_devices, "base load length");
        let sw = crate::util::timer::Stopwatch::start();
        let uniform_only = matches!(self.kind, SchedulerKind::Uniform);
        let in_warmup = round < self.warmup_rounds;
        if uniform_only || in_warmup {
            let assignment = uniform_assign_masked(clients, alive);
            let predicted = vec![0.0; self.n_devices];
            return Schedule {
                assignment,
                predicted,
                overhead_secs: sw.elapsed_secs(),
                used_model: false,
                estimates: None,
            };
        }
        // Time-Window kinds never look behind round − τ again, so the
        // stale records can go — this is also what bounds history memory
        // on long runs.  saturating_sub: scheduling at round < τ must
        // not underflow (and prunes nothing).
        if let Some(w) = self.window() {
            self.history.prune(round.saturating_sub(w));
        }
        let window = self.window();
        let estimates = self.history.estimate(self.n_devices, round, window);
        let penalty = self.affinity_penalty();
        let (assignment, predicted) = if penalty > 0.0 {
            let ctx = self.affinity.as_ref().expect("penalty > 0 implies ctx");
            let extra = |client: usize, dev: usize| {
                if ctx.owner_worker(client) == dev {
                    0.0
                } else {
                    penalty
                }
            };
            greedy_assign_with_cost(clients, &estimates, alive, base_load, &extra)
        } else {
            greedy_assign_from(clients, &estimates, alive, base_load)
        };
        Schedule {
            assignment,
            predicted,
            overhead_secs: sw.elapsed_secs(),
            used_model: true,
            estimates: Some(estimates),
        }
    }

    /// Re-place tasks orphaned by a mid-round device departure: the
    /// same greedy min-max step (Eq. 4) over the surviving devices,
    /// starting from each survivor's already-committed `base_load`
    /// predicted seconds.  Returns per-device lists of the orphaned
    /// ids (the caller's task/client handles).
    ///
    /// Deliberately affinity-free: the handles here are the caller's
    /// opaque task ids (not client ids), and a departure hands the
    /// dead worker's shard off anyway, so plan-time ownership is
    /// already stale by the time orphans move.
    pub fn reassign_orphans(
        &mut self,
        round: usize,
        orphans: &[(usize, usize)],
        alive: &[bool],
        base_load: &[f64],
    ) -> Vec<Vec<usize>> {
        if orphans.is_empty() || !alive.iter().any(|&a| a) {
            return vec![Vec::new(); self.n_devices];
        }
        let window = self.window();
        let estimates = self.history.estimate(self.n_devices, round, window);
        greedy_assign_from(orphans, &estimates, alive, base_load).0
    }

    /// Forget a departed device's runtime records (its slot may later
    /// host different hardware — see [`History::prune_device`]).
    pub fn prune_device(&mut self, device: usize) {
        self.history.prune_device(device);
    }

    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    fn window(&self) -> Option<usize> {
        match self.kind {
            SchedulerKind::TimeWindow(t) => Some(t),
            SchedulerKind::StateAffinity { window, .. } if window > 0 => Some(window),
            _ => None,
        }
    }

    /// Current per-device estimates (Fig. 6 visualization).
    pub fn estimates(&self, round: usize) -> Vec<DeviceEstimate> {
        self.history.estimate(self.n_devices, round, self.window())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn clients(sizes: &[usize]) -> Vec<(usize, usize)> {
        sizes.iter().cloned().enumerate().collect()
    }

    #[test]
    fn warmup_uses_uniform() {
        let mut s = Scheduler::new(SchedulerKind::Greedy, 2, 4);
        let sch = s.schedule(0, &clients(&[50, 40, 30, 20, 10, 5, 4, 3]));
        assert!(!sch.used_model);
        assert_eq!(sch.assignment.len(), 4);
        let total: usize = sch.assignment.iter().map(|a| a.len()).sum();
        assert_eq!(total, 8);
    }

    #[test]
    fn after_warmup_uses_model() {
        let mut s = Scheduler::new(SchedulerKind::Greedy, 1, 2);
        // Seed history: device 0 twice as fast.
        for r in 0..3 {
            for (n, d, t) in [(100, 0, 1.0), (200, 0, 2.0), (100, 1, 2.0), (200, 1, 4.0)] {
                s.record(TaskRecord { round: r, device: d, n_samples: n, secs: t });
            }
        }
        let sch = s.schedule(3, &clients(&[100, 100, 100]));
        assert!(sch.used_model);
        // Fast device should get more work.
        assert!(sch.assignment[0].len() >= sch.assignment[1].len());
        let total: usize = sch.assignment.iter().map(|a| a.len()).sum();
        assert_eq!(total, 3);
    }

    #[test]
    fn uniform_kind_never_models() {
        let mut s = Scheduler::new(SchedulerKind::Uniform, 0, 2);
        for r in 0..5 {
            s.record(TaskRecord { round: r, device: 0, n_samples: 10, secs: 1.0 });
        }
        assert!(!s.schedule(10, &clients(&[1, 2, 3])).used_model);
    }

    #[test]
    fn masked_schedule_avoids_dead_devices() {
        let mut s = Scheduler::new(SchedulerKind::Greedy, 0, 3);
        for r in 0..3 {
            for d in 0..3 {
                s.record(TaskRecord { round: r, device: d, n_samples: 100, secs: 1.0 });
                s.record(TaskRecord { round: r, device: d, n_samples: 200, secs: 2.0 });
            }
        }
        let sch = s.schedule_masked(3, &clients(&[50, 40, 30, 20]), &[true, false, true]);
        assert!(sch.used_model);
        assert!(sch.assignment[1].is_empty(), "{:?}", sch.assignment);
        let total: usize = sch.assignment.iter().map(|a| a.len()).sum();
        assert_eq!(total, 4);
        // uniform branch honors the mask too
        let mut u = Scheduler::new(SchedulerKind::Uniform, 0, 3);
        let sch = u.schedule_masked(0, &clients(&[50, 40, 30, 20]), &[false, true, true]);
        assert!(sch.assignment[0].is_empty());
    }

    #[test]
    fn reassign_orphans_prefers_lightly_loaded_survivors() {
        let mut s = Scheduler::new(SchedulerKind::Greedy, 0, 3);
        for r in 0..2 {
            for d in 0..3 {
                s.record(TaskRecord { round: r, device: d, n_samples: 100, secs: 1.0 });
                s.record(TaskRecord { round: r, device: d, n_samples: 300, secs: 3.0 });
            }
        }
        // device 0 departed; device 1 is nearly free, device 2 is loaded
        let placed = s.reassign_orphans(
            2,
            &[(7, 100), (8, 100), (9, 100)],
            &[false, true, true],
            &[0.0, 0.5, 30.0],
        );
        assert!(placed[0].is_empty(), "{placed:?}");
        assert_eq!(placed.iter().map(|p| p.len()).sum::<usize>(), 3);
        assert!(placed[1].len() >= placed[2].len(), "{placed:?}");
        // no survivors -> nothing placed (caller drops the tasks)
        let none = s.reassign_orphans(2, &[(1, 10)], &[false, false, false], &[0.0, 0.0, 0.0]);
        assert!(none.iter().all(|p| p.is_empty()));
    }

    #[test]
    fn prune_device_forgets_history() {
        let mut s = Scheduler::new(SchedulerKind::Greedy, 0, 2);
        s.record(TaskRecord { round: 0, device: 0, n_samples: 10, secs: 1.0 });
        s.record(TaskRecord { round: 0, device: 1, n_samples: 10, secs: 1.0 });
        s.prune_device(0);
        assert_eq!(s.history.len(), 1);
        assert!(s.history.records().iter().all(|r| r.device == 1));
    }

    #[test]
    fn state_affinity_prefers_owner_workers() {
        use crate::statestore::ShardMap;
        let map = ShardMap::new(3);
        let mk = |kind| {
            let mut s = Scheduler::new(kind, 0, 3);
            for r in 0..3 {
                for d in 0..3 {
                    s.record(TaskRecord { round: r, device: d, n_samples: 100, secs: 1.0 });
                    s.record(TaskRecord { round: r, device: d, n_samples: 200, secs: 2.0 });
                }
            }
            s.set_affinity(Some(AffinityCtx {
                map: map.clone(),
                n_workers: 3,
                remote_secs: 1e5, // dwarfs any compute imbalance
            }));
            s
        };
        let cs = clients(&[100, 100, 100, 100, 100, 100, 100, 100, 100]);
        let mut aff = mk(SchedulerKind::StateAffinity { window: 0, weight_pct: 100 });
        let sch = aff.schedule(3, &cs);
        assert!(sch.used_model);
        for (dev, list) in sch.assignment.iter().enumerate() {
            for &c in list {
                assert_eq!(
                    map.owner(c as u64) as usize,
                    dev,
                    "client {c} scheduled off-owner: {:?}",
                    sch.assignment
                );
            }
        }
        // Same context on a plain Greedy kind: the term must not bite.
        let mut plain = mk(SchedulerKind::Greedy);
        let sp = plain.schedule(3, &cs);
        let spread = |a: &[Vec<usize>]| a.iter().map(|l| l.len()).max().unwrap();
        assert!(spread(&sp.assignment) <= 4, "greedy stays balanced: {:?}", sp.assignment);
        // Affinity with zero weight degrades to plain greedy too.
        let mut zero = mk(SchedulerKind::StateAffinity { window: 0, weight_pct: 0 });
        assert_eq!(zero.schedule(3, &cs).assignment, sp.assignment);
        // The windowed variant threads its window through estimation.
        let w = Scheduler::new(SchedulerKind::StateAffinity { window: 4, weight_pct: 50 }, 0, 3);
        assert_eq!(w.window(), Some(4));
    }

    #[test]
    fn window_prune_bounds_history_and_survives_early_rounds() {
        let mut s = Scheduler::new(SchedulerKind::TimeWindow(3), 0, 2);
        for r in 0..10 {
            for d in 0..2 {
                s.record(TaskRecord { round: r, device: d, n_samples: 100, secs: 1.0 });
                s.record(TaskRecord { round: r, device: d, n_samples: 200, secs: 2.0 });
            }
        }
        // round < window: saturating_sub keeps everything, no underflow.
        let sch = s.schedule(2, &clients(&[50, 40]));
        assert!(sch.used_model);
        assert_eq!(s.history.len(), 40, "nothing pruned before the window fills");
        // Past the window, records older than round − τ are dropped —
        // exactly the set the windowed estimate would never read again.
        s.schedule(10, &clients(&[50, 40]));
        assert!(s.history.records().iter().all(|r| r.round >= 7), "{:?}", s.history.len());
        assert_eq!(s.history.len(), 3 * 2 * 2);
        // Un-windowed kinds keep full history.
        let mut g = Scheduler::new(SchedulerKind::Greedy, 0, 2);
        for r in 0..10 {
            g.record(TaskRecord { round: r, device: 0, n_samples: 100, secs: 1.0 });
            g.record(TaskRecord { round: r, device: 1, n_samples: 200, secs: 2.0 });
        }
        g.schedule(10, &clients(&[50, 40]));
        assert_eq!(g.history.len(), 20);
    }

    #[test]
    fn schedule_from_zero_base_matches_schedule_masked() {
        let seed_records = |s: &mut Scheduler| {
            for r in 0..3 {
                for d in 0..3 {
                    s.record(TaskRecord { round: r, device: d, n_samples: 100, secs: 1.0 });
                    s.record(TaskRecord { round: r, device: d, n_samples: 200, secs: 2.0 });
                }
            }
        };
        let cs = clients(&[90, 70, 50, 30, 20, 10]);
        let mut a = Scheduler::new(SchedulerKind::Greedy, 0, 3);
        let mut b = Scheduler::new(SchedulerKind::Greedy, 0, 3);
        seed_records(&mut a);
        seed_records(&mut b);
        let alive = [true, true, true];
        let sa = a.schedule_masked(3, &cs, &alive);
        let sb = b.schedule_from(3, &cs, &alive, &[0.0, 0.0, 0.0]);
        assert_eq!(sa.assignment, sb.assignment);
        assert_eq!(sa.predicted, sb.predicted);
        // A loaded device receives less incremental work.
        let mut c = Scheduler::new(SchedulerKind::Greedy, 0, 3);
        seed_records(&mut c);
        let sc = c.schedule_from(3, &cs, &alive, &[100.0, 0.0, 0.0]);
        assert!(
            sc.assignment[0].len() <= sb.assignment[0].len(),
            "{:?} vs {:?}",
            sc.assignment,
            sb.assignment
        );
        assert!(sc.assignment[0].is_empty(), "100s head start dwarfs this cohort");
    }

    #[test]
    fn overhead_is_measured() {
        let mut s = Scheduler::new(SchedulerKind::Greedy, 0, 8);
        for r in 0..3 {
            for k in 0..8 {
                s.record(TaskRecord { round: r, device: k, n_samples: 100, secs: 1.0 });
                s.record(TaskRecord { round: r, device: k, n_samples: 200, secs: 1.9 });
            }
        }
        let sch = s.schedule(5, &clients(&(1..200).collect::<Vec<_>>()));
        assert!(sch.overhead_secs >= 0.0);
        assert!(sch.used_model);
    }
}
