//! Heterogeneity-aware task scheduling (paper §4.3–§4.4, Alg. 3).
//!
//! - [`workload`] — the per-device workload model T_{m,k} = N_m·t_k + b_k
//!   (Eq. 2) fitted by OLS over recorded task runtimes, with either full
//!   history or the Time-Window restriction (§4.4 "Tackling Dynamic
//!   Hardware Environments").
//! - [`greedy`] — Alg. 3's LPT-style min-max assignment: sort clients by
//!   size descending, place each on the device that minimizes the
//!   resulting makespan (Eq. 3–4).
//!
//! The [`Scheduler`] facade ties both to the config's
//! [`SchedulerKind`](crate::config::SchedulerKind) and owns the history.

pub mod greedy;
pub mod workload;

pub use greedy::{greedy_assign, uniform_assign};
pub use workload::{DeviceEstimate, History, TaskRecord};

use crate::config::SchedulerKind;

/// Outcome of scheduling one round.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Per-device client-index lists: `assignment[k]` = clients for device k.
    pub assignment: Vec<Vec<usize>>,
    /// Predicted per-device busy time (seconds) under the fitted model.
    pub predicted: Vec<f64>,
    /// Wallclock cost of estimation + assignment (Fig. 8's metric).
    pub overhead_secs: f64,
    /// Whether the fitted model (vs the warm-up uniform split) was used.
    pub used_model: bool,
}

/// Stateful scheduler: owns the runtime history and applies Alg. 3.
pub struct Scheduler {
    pub kind: SchedulerKind,
    pub warmup_rounds: usize,
    pub history: History,
    n_devices: usize,
}

impl Scheduler {
    pub fn new(kind: SchedulerKind, warmup_rounds: usize, n_devices: usize) -> Scheduler {
        Scheduler { kind, warmup_rounds, history: History::new(), n_devices }
    }

    /// Record a finished task (device k ran `n_eff` effective samples in
    /// `secs` at round r) — what devices piggyback on their result
    /// messages (§4.3 Estimation).
    pub fn record(&mut self, rec: TaskRecord) {
        self.history.push(rec);
    }

    /// Schedule `clients` = (client id, effective samples N_m·E) for round `r`.
    pub fn schedule(&mut self, round: usize, clients: &[(usize, usize)]) -> Schedule {
        let sw = crate::util::timer::Stopwatch::start();
        let uniform_only = matches!(self.kind, SchedulerKind::Uniform);
        let in_warmup = round < self.warmup_rounds;
        if uniform_only || in_warmup {
            let assignment = uniform_assign(clients, self.n_devices);
            let predicted = vec![0.0; self.n_devices];
            return Schedule {
                assignment,
                predicted,
                overhead_secs: sw.elapsed_secs(),
                used_model: false,
            };
        }
        let window = match self.kind {
            SchedulerKind::TimeWindow(t) => Some(t),
            _ => None,
        };
        let estimates = self.history.estimate(self.n_devices, round, window);
        let (assignment, predicted) = greedy_assign(clients, &estimates);
        Schedule {
            assignment,
            predicted,
            overhead_secs: sw.elapsed_secs(),
            used_model: true,
        }
    }

    /// Current per-device estimates (Fig. 6 visualization).
    pub fn estimates(&self, round: usize) -> Vec<DeviceEstimate> {
        let window = match self.kind {
            SchedulerKind::TimeWindow(t) => Some(t),
            _ => None,
        };
        self.history.estimate(self.n_devices, round, window)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn clients(sizes: &[usize]) -> Vec<(usize, usize)> {
        sizes.iter().cloned().enumerate().collect()
    }

    #[test]
    fn warmup_uses_uniform() {
        let mut s = Scheduler::new(SchedulerKind::Greedy, 2, 4);
        let sch = s.schedule(0, &clients(&[50, 40, 30, 20, 10, 5, 4, 3]));
        assert!(!sch.used_model);
        assert_eq!(sch.assignment.len(), 4);
        let total: usize = sch.assignment.iter().map(|a| a.len()).sum();
        assert_eq!(total, 8);
    }

    #[test]
    fn after_warmup_uses_model() {
        let mut s = Scheduler::new(SchedulerKind::Greedy, 1, 2);
        // Seed history: device 0 twice as fast.
        for r in 0..3 {
            for (n, d, t) in [(100, 0, 1.0), (200, 0, 2.0), (100, 1, 2.0), (200, 1, 4.0)] {
                s.record(TaskRecord { round: r, device: d, n_samples: n, secs: t });
            }
        }
        let sch = s.schedule(3, &clients(&[100, 100, 100]));
        assert!(sch.used_model);
        // Fast device should get more work.
        assert!(sch.assignment[0].len() >= sch.assignment[1].len());
        let total: usize = sch.assignment.iter().map(|a| a.len()).sum();
        assert_eq!(total, 3);
    }

    #[test]
    fn uniform_kind_never_models() {
        let mut s = Scheduler::new(SchedulerKind::Uniform, 0, 2);
        for r in 0..5 {
            s.record(TaskRecord { round: r, device: 0, n_samples: 10, secs: 1.0 });
        }
        assert!(!s.schedule(10, &clients(&[1, 2, 3])).used_model);
    }

    #[test]
    fn overhead_is_measured() {
        let mut s = Scheduler::new(SchedulerKind::Greedy, 0, 8);
        for r in 0..3 {
            for k in 0..8 {
                s.record(TaskRecord { round: r, device: k, n_samples: 100, secs: 1.0 });
                s.record(TaskRecord { round: r, device: k, n_samples: 200, secs: 1.9 });
            }
        }
        let sch = s.schedule(5, &clients(&(1..200).collect::<Vec<_>>()));
        assert!(sch.overhead_secs >= 0.0);
        assert!(sch.used_model);
    }
}
