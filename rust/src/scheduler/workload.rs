//! The workload model (Eq. 1–2) and its estimation from history (§4.3).
//!
//! Each finished task contributes one (N_m, T̂) point for its device;
//! the server fits per-device OLS `T = t_k·N + b_k`.  Time-Window
//! estimation (§4.4) restricts the fit to records from the last τ
//! rounds, which is what keeps the model honest under the cos-law
//! dynamic environments (Fig. 11).

use crate::util::stats::linear_regression;

/// One recorded task runtime.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TaskRecord {
    pub round: usize,
    pub device: usize,
    /// Effective samples processed: N_m · local_epochs.
    pub n_samples: usize,
    /// Measured wallclock seconds (including any heterogeneity sleep —
    /// the server only ever sees the total, as in the paper).
    pub secs: f64,
}

/// Fitted per-device workload model.
#[derive(Debug, Clone, Copy)]
pub struct DeviceEstimate {
    /// Seconds per effective sample (t_k in Eq. 2).
    pub t_sample: f64,
    /// Fixed per-task seconds (b_k in Eq. 2).
    pub b: f64,
    /// Fit quality (1.0 = perfect).
    pub r2: f64,
    /// Points used.
    pub n_points: usize,
}

impl DeviceEstimate {
    /// Predicted task time for `n` effective samples (Eq. 2).
    ///
    /// A degenerate fit (NaN/∞ coefficients, e.g. OLS fed garbage
    /// runtimes) predicts +∞ rather than leaking NaN into the greedy
    /// comparisons — NaN compares false against everything, which would
    /// otherwise let a broken device silently win (or lose) every
    /// placement.
    pub fn predict(&self, n: usize) -> f64 {
        let t = self.t_sample * n as f64 + self.b;
        if !t.is_finite() {
            return f64::INFINITY;
        }
        t.max(0.0)
    }
}

/// Append-only runtime history with windowed per-device OLS.
#[derive(Debug, Default)]
pub struct History {
    records: Vec<TaskRecord>,
}

impl History {
    pub fn new() -> History {
        History::default()
    }

    pub fn push(&mut self, rec: TaskRecord) {
        self.records.push(rec);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn records(&self) -> &[TaskRecord] {
        &self.records
    }

    /// Drop records older than `before_round` (bounds memory on long
    /// runs; Time-Window users call this with r − τ).
    pub fn prune(&mut self, before_round: usize) {
        self.records.retain(|r| r.round >= before_round);
    }

    /// Drop every record for `device` — called when a device departs
    /// the cluster.  Its slot may later be re-filled by a different
    /// physical device (DeviceJoin), whose workload model must be
    /// re-learned from scratch rather than inherited from the old
    /// hardware's runtimes.
    pub fn prune_device(&mut self, device: usize) {
        self.records.retain(|r| r.device != device);
    }

    /// Fit Eq. 2 for each of `k` devices at scheduling round `round`,
    /// using only records within `window` rounds when given
    /// (`Estimate_Workload` in Alg. 3).
    ///
    /// Fallback ladder when a device's design is unfittable:
    /// 1. fewer than 2 points or constant-N → ratio estimator
    ///    t = mean(T)/mean(N), b = 0;
    /// 2. no points at all → global mean ratio across devices;
    /// 3. empty history → t = 1, b = 0 (arbitrary but uniform, so the
    ///    greedy pass degenerates to balanced-size assignment).
    pub fn estimate(
        &self,
        k: usize,
        round: usize,
        window: Option<usize>,
    ) -> Vec<DeviceEstimate> {
        let lo = window.map(|w| round.saturating_sub(w)).unwrap_or(0);
        let mut xs: Vec<Vec<f64>> = vec![Vec::new(); k];
        let mut ys: Vec<Vec<f64>> = vec![Vec::new(); k];
        let mut all_n = 0.0;
        let mut all_t = 0.0;
        for r in &self.records {
            if r.round < lo || r.device >= k {
                continue;
            }
            xs[r.device].push(r.n_samples as f64);
            ys[r.device].push(r.secs);
            all_n += r.n_samples as f64;
            all_t += r.secs;
        }
        let global_ratio = if all_n > 0.0 && (all_t / all_n).is_finite() {
            all_t / all_n
        } else {
            1.0
        };
        (0..k)
            .map(|d| {
                if let Some(fit) = linear_regression(&xs[d], &ys[d]) {
                    // Negative slope or intercept can appear under heavy
                    // noise; clamp to the physical region.  Non-finite
                    // coefficients (∞ runtimes in the design) fall
                    // through to the ratio ladder instead of poisoning
                    // the greedy comparisons.
                    if fit.slope.is_finite() && fit.intercept.is_finite() {
                        let t_sample = fit.slope.max(1e-9);
                        let b = fit.intercept.max(0.0);
                        return DeviceEstimate { t_sample, b, r2: fit.r2, n_points: fit.n };
                    }
                }
                if !xs[d].is_empty() {
                    let t = ys[d].iter().sum::<f64>() / xs[d].iter().sum::<f64>().max(1e-9);
                    if t.is_finite() {
                        return DeviceEstimate {
                            t_sample: t.max(1e-9),
                            b: 0.0,
                            r2: 0.0,
                            n_points: xs[d].len(),
                        };
                    }
                }
                DeviceEstimate { t_sample: global_ratio.max(1e-9), b: 0.0, r2: 0.0, n_points: 0 }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, device: usize, n: usize, secs: f64) -> TaskRecord {
        TaskRecord { round, device, n_samples: n, secs }
    }

    #[test]
    fn recovers_exact_model() {
        let mut h = History::new();
        // device 0: T = 0.01 N + 0.5 ; device 1: T = 0.02 N + 1.0
        for &n in &[50, 100, 150, 200] {
            h.push(rec(0, 0, n, 0.01 * n as f64 + 0.5));
            h.push(rec(0, 1, n, 0.02 * n as f64 + 1.0));
        }
        let est = h.estimate(2, 1, None);
        assert!((est[0].t_sample - 0.01).abs() < 1e-9);
        assert!((est[0].b - 0.5).abs() < 1e-9);
        assert!((est[1].t_sample - 0.02).abs() < 1e-9);
        assert!((est[1].b - 1.0).abs() < 1e-9);
        assert!((est[0].predict(300) - 3.5).abs() < 1e-9);
    }

    #[test]
    fn window_discards_stale_regime() {
        let mut h = History::new();
        // Rounds 0-9: slow regime (t=0.1); rounds 10-19: fast (t=0.01).
        for r in 0..10 {
            for &n in &[50, 150] {
                h.push(rec(r, 0, n, 0.1 * n as f64));
            }
        }
        for r in 10..20 {
            for &n in &[50, 150] {
                h.push(rec(r, 0, n, 0.01 * n as f64));
            }
        }
        let full = h.estimate(1, 20, None)[0];
        let windowed = h.estimate(1, 20, Some(5))[0];
        // Full history blends regimes; window nails the current one.
        assert!((windowed.t_sample - 0.01).abs() < 1e-6);
        assert!(full.t_sample > 0.03, "full={}", full.t_sample);
    }

    #[test]
    fn single_point_ratio_fallback() {
        let mut h = History::new();
        h.push(rec(0, 0, 100, 2.0));
        let est = h.estimate(1, 1, None);
        assert!((est[0].t_sample - 0.02).abs() < 1e-9);
        assert_eq!(est[0].b, 0.0);
    }

    #[test]
    fn constant_n_ratio_fallback() {
        let mut h = History::new();
        h.push(rec(0, 0, 100, 2.0));
        h.push(rec(1, 0, 100, 2.2));
        let est = h.estimate(1, 2, None);
        assert!(est[0].t_sample > 0.0);
    }

    #[test]
    fn unseen_device_gets_global_ratio() {
        let mut h = History::new();
        h.push(rec(0, 0, 100, 1.0));
        h.push(rec(0, 0, 200, 2.0));
        let est = h.estimate(2, 1, None);
        assert!((est[1].t_sample - 0.01).abs() < 1e-6);
        assert_eq!(est[1].n_points, 0);
    }

    #[test]
    fn empty_history_uniform() {
        let h = History::new();
        let est = h.estimate(3, 0, None);
        assert!(est.iter().all(|e| e.t_sample == est[0].t_sample));
    }

    #[test]
    fn prune_drops_old() {
        let mut h = History::new();
        for r in 0..10 {
            h.push(rec(r, 0, 10, 1.0));
        }
        h.prune(7);
        assert_eq!(h.len(), 3);
        assert!(h.records().iter().all(|r| r.round >= 7));
    }

    #[test]
    fn prune_device_drops_only_that_device() {
        let mut h = History::new();
        for r in 0..4 {
            h.push(rec(r, 0, 100, 1.0));
            h.push(rec(r, 1, 100, 2.0));
        }
        h.prune_device(0);
        assert_eq!(h.len(), 4);
        assert!(h.records().iter().all(|r| r.device == 1));
        // the departed device falls back to the global-ratio estimate
        let est = h.estimate(2, 4, None);
        assert_eq!(est[0].n_points, 0);
        assert!(est[1].n_points > 0);
    }

    #[test]
    fn degenerate_fit_predicts_infinity_not_nan() {
        // NaN/∞ coefficients must surface as +∞ predictions (never NaN):
        // the greedy pass skips infinite candidates explicitly, while a
        // NaN would silently falsify every comparison.
        for bad in [f64::NAN, f64::INFINITY] {
            let e = DeviceEstimate { t_sample: bad, b: 0.1, r2: 0.0, n_points: 1 };
            assert_eq!(e.predict(100), f64::INFINITY, "t_sample={bad}");
            let e = DeviceEstimate { t_sample: 0.01, b: bad, r2: 0.0, n_points: 1 };
            assert_eq!(e.predict(100), f64::INFINITY, "b={bad}");
        }
        // finite fits are untouched
        let e = DeviceEstimate { t_sample: 0.01, b: 0.5, r2: 1.0, n_points: 4 };
        assert!((e.predict(100) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn infinite_runtimes_fall_through_the_estimate_ladder() {
        // A device whose recorded secs are ∞ (e.g. a wedged executor
        // clock) must not produce non-finite coefficients.
        let mut h = History::new();
        h.push(rec(0, 0, 100, f64::INFINITY));
        h.push(rec(0, 0, 200, f64::INFINITY));
        h.push(rec(0, 1, 100, 1.0));
        h.push(rec(0, 1, 200, 2.0));
        let est = h.estimate(2, 1, None);
        for (d, e) in est.iter().enumerate() {
            assert!(
                e.t_sample.is_finite() && e.b.is_finite(),
                "device {d}: {e:?}"
            );
        }
        assert!((est[1].t_sample - 0.01).abs() < 1e-9, "healthy device unaffected");
    }

    #[test]
    fn negative_fit_clamped() {
        let mut h = History::new();
        // Pathological: time decreasing in N.
        h.push(rec(0, 0, 100, 5.0));
        h.push(rec(0, 0, 200, 1.0));
        let est = h.estimate(1, 1, None);
        assert!(est[0].t_sample > 0.0);
        assert!(est[0].b >= 0.0);
    }
}
