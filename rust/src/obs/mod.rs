//! Unified deterministic observability: typed span/event tracing plus
//! a named-metrics registry, shared by the virtual-time engine and the
//! real coordinator path, with Chrome trace-event export ([`chrome`]).
//!
//! One API, two clocks: the engine emits events in *virtual* seconds
//! under its `(time, seq)` merge key (so per-shard buffers merge into
//! the same sequence for any `--threads N` and the rendered file is
//! byte-identical per seed — pinned in `tests/determinism.rs`), while
//! the server/worker path emits the same [`Ev`] values with *wallclock*
//! seconds measured by its own `Stopwatch`.  The tracer is an `Option`
//! sink everywhere: disabled runs carry a `None` and pay only a branch.
//!
//! `obs` is a strict `parrot lint` root: no `Hash*` containers, no
//! ambient clocks — every timestamp is an argument, never sampled here.

pub mod chrome;
pub mod registry;

pub use registry::Registry;

/// One horizontal lane of the exported timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Track {
    /// Run-level framing: round / flush-interval spans.
    Run,
    /// Server lane: aggregation tails, state flushes, async flush chains.
    Server,
    /// Executor `i`'s compute lane.
    Device(usize),
    /// Executor `i`'s NIC lane (upload/download legs).
    Net(usize),
}

/// What happened.  Field order is the rendered `args` order — keep it
/// stable, the trace differential compares bytes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EvKind {
    /// One client task's compute on an executor.
    Task { task: usize, client: usize },
    /// A task cut short (client became unavailable / device left).
    TaskAborted { task: usize },
    /// Client-state staging before compute (prefetch stall or a
    /// deploy-side batched prefetch of `clients` states).
    StateLoad { clients: usize },
    /// Download leg (params to the executor) for one task.
    CommDown { task: usize, bytes: u64 },
    /// Upload leg (aggregate back) after one task.
    CommUp { task: usize, bytes: u64 },
    /// The hierarchical aggregation tail (LAN fold + WAN crossing).
    Tail { bytes: u64, cross_bytes: u64, group_aggs: usize },
    /// State write-back leg at the end of the tail.
    StateFlush { bytes: u64 },
    /// One async buffered flush (merge + re-broadcast).
    Flush { flush: usize, applied: usize, stale: usize },
    /// A scheduler decision (placement of `placed` tasks).
    Sched { round: usize, placed: usize },
    /// Round / flush-interval framing span.
    Round { round: usize },
    DeviceLeave { device: usize },
    DeviceJoin { device: usize },
    /// State-shard ownership movement after churn.
    ShardTransfer { worker: usize, bytes: u64 },
}

impl EvKind {
    /// Chrome event name (the `name` field — one per variant).
    pub fn name(&self) -> &'static str {
        match self {
            EvKind::Task { .. } => "task",
            EvKind::TaskAborted { .. } => "task-aborted",
            EvKind::StateLoad { .. } => "state-load",
            EvKind::CommDown { .. } => "comm-down",
            EvKind::CommUp { .. } => "comm-up",
            EvKind::Tail { .. } => "tail",
            EvKind::StateFlush { .. } => "state-flush",
            EvKind::Flush { .. } => "flush",
            EvKind::Sched { .. } => "sched",
            EvKind::Round { .. } => "round",
            EvKind::DeviceLeave { .. } => "device-leave",
            EvKind::DeviceJoin { .. } => "device-join",
            EvKind::ShardTransfer { .. } => "shard-transfer",
        }
    }
}

/// One trace event: a span when `t1 > t0`, an instant otherwise.
///
/// `(at, seq)` is the deterministic order key: the engine stamps the
/// emitting pop's `(time bits, namespaced seq)` so per-shard buffers
/// merge exactly like the event queue itself; tracer-level emitters
/// get a private monotone sequence.  `t0`/`t1` are seconds on the
/// emitter's clock (virtual or wallclock).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Ev {
    pub at: u64,
    pub seq: u64,
    pub t0: f64,
    pub t1: f64,
    pub track: Track,
    pub kind: EvKind,
}

/// An append-only event sink.  Engine rounds record into plain
/// `Vec<Ev>` buffers (merged on `(at, seq)`); the run-level tracer
/// absorbs those per-round buffers shifted onto the run's clock and
/// takes run-level emissions (round framing, scheduler decisions,
/// churn-driven shard transfers) directly.
#[derive(Debug, Default)]
pub struct Tracer {
    pub events: Vec<Ev>,
    seq: u64,
}

impl Tracer {
    pub fn new() -> Tracer {
        Tracer::default()
    }

    fn next_key(&mut self, t0: f64) -> (u64, u64) {
        let k = (t0.to_bits(), self.seq);
        self.seq += 1;
        k
    }

    /// Record a span `[t0, t1]`.
    pub fn span(&mut self, t0: f64, t1: f64, track: Track, kind: EvKind) {
        let (at, seq) = self.next_key(t0);
        self.events.push(Ev { at, seq, t0, t1, track, kind });
    }

    /// Record a zero-width instant at `t`.
    pub fn instant(&mut self, t: f64, track: Track, kind: EvKind) {
        self.span(t, t, track, kind);
    }

    /// Absorb one engine round's merged buffer, shifting its (round-
    /// local) virtual times by `offset` onto the run clock.  The
    /// buffer's own `(at, seq)` order is preserved as file order.
    pub fn absorb(&mut self, events: &[Ev], offset: f64) {
        for e in events {
            let mut e = *e;
            e.t0 += offset;
            e.t1 += offset;
            e.seq = self.seq;
            self.seq += 1;
            self.events.push(e);
        }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_and_instants_keep_order() {
        let mut t = Tracer::new();
        t.span(0.0, 1.5, Track::Device(0), EvKind::Task { task: 0, client: 7 });
        t.instant(1.5, Track::Server, EvKind::DeviceLeave { device: 2 });
        assert_eq!(t.events.len(), 2);
        assert_eq!(t.events[0].kind.name(), "task");
        assert!(t.events[0].t1 > t.events[0].t0);
        // Instants collapse to t1 == t0.
        assert_eq!(t.events[1].t0, t.events[1].t1);
        assert!(t.events[0].seq < t.events[1].seq);
    }

    #[test]
    fn absorb_shifts_onto_the_run_clock() {
        let mut t = Tracer::new();
        let round: Vec<Ev> = vec![Ev {
            at: 0,
            seq: 3,
            t0: 1.0,
            t1: 2.0,
            track: Track::Net(1),
            kind: EvKind::CommUp { task: 4, bytes: 10 },
        }];
        t.absorb(&round, 100.0);
        assert_eq!(t.events[0].t0, 101.0);
        assert_eq!(t.events[0].t1, 102.0);
        // The run-level sequence replaces the engine's round-local one.
        assert_eq!(t.events[0].seq, 0);
    }
}
