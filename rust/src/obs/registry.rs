//! Named counters and log-bucketed histograms, Vec-backed so the
//! rendered output is a pure function of the recorded values — no
//! `Hash*` iteration order anywhere near it.  Registries on the sim
//! and deploy paths use the same dotted names (`area.metric`, e.g.
//! `async.flushes`, `transport.sent_bytes`), which is what makes the
//! sim-vs-deploy counter-parity differential a byte comparison.

use crate::util::json::Json;

/// A power-of-two histogram: bucket 0 counts zeros, bucket `b >= 1`
/// counts values in `[2^(b-1), 2^b)`.  Pure integer math — no float
/// log, so bucketing is identical on every host.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Hist {
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: u64,
}

impl Hist {
    pub fn observe(&mut self, v: u64) {
        let b = (u64::BITS - v.leading_zeros()) as usize;
        if self.buckets.len() <= b {
            self.buckets.resize(b + 1, 0);
        }
        self.buckets[b] += 1;
        self.count += 1;
        self.sum += v;
    }
}

/// The registry: linear-scan name lookup (metric cardinality is tens,
/// not thousands), render-time name sort so two registries filled in
/// different orders still render identically.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    counters: Vec<(String, u64)>,
    hists: Vec<(String, Hist)>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Add `delta` to counter `name` (created at 0 on first touch).
    pub fn add(&mut self, name: &str, delta: u64) {
        match self.counters.iter_mut().find(|(n, _)| n == name) {
            Some((_, v)) => *v += delta,
            None => self.counters.push((name.to_string(), delta)),
        }
    }

    pub fn inc(&mut self, name: &str) {
        self.add(name, 1);
    }

    pub fn get(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| *v)
            .unwrap_or(0)
    }

    /// Record one sample into histogram `name`.
    pub fn observe(&mut self, name: &str, v: u64) {
        match self.hists.iter_mut().find(|(n, _)| n == name) {
            Some((_, h)) => h.observe(v),
            None => {
                let mut h = Hist::default();
                h.observe(v);
                self.hists.push((name.to_string(), h));
            }
        }
    }

    /// Seconds sample, bucketed at microsecond resolution.
    pub fn observe_secs(&mut self, name: &str, secs: f64) {
        self.observe(name, (secs.max(0.0) * 1e6) as u64);
    }

    pub fn hist(&self, name: &str) -> Option<&Hist> {
        self.hists.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }

    /// Stable render: names sorted, buckets as-is (already dense).
    pub fn to_json(&self) -> Json {
        let mut counters: Vec<&(String, u64)> = self.counters.iter().collect();
        counters.sort_by(|a, b| a.0.cmp(&b.0));
        let mut hists: Vec<&(String, Hist)> = self.hists.iter().collect();
        hists.sort_by(|a, b| a.0.cmp(&b.0));
        Json::obj()
            .set(
                "counters",
                Json::Obj(
                    counters
                        .into_iter()
                        .map(|(n, v)| (n.clone(), Json::Int(*v as i64)))
                        .collect(),
                ),
            )
            .set(
                "histograms",
                Json::Obj(
                    hists
                        .into_iter()
                        .map(|(n, h)| {
                            (
                                n.clone(),
                                Json::obj()
                                    .set(
                                        "buckets",
                                        Json::Arr(
                                            h.buckets
                                                .iter()
                                                .map(|&b| Json::Int(b as i64))
                                                .collect(),
                                        ),
                                    )
                                    .set("count", Json::Int(h.count as i64))
                                    .set("sum", Json::Int(h.sum as i64)),
                            )
                        })
                        .collect(),
                ),
            )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_default_to_zero() {
        let mut r = Registry::new();
        r.inc("a.x");
        r.add("a.x", 4);
        r.add("a.y", 2);
        assert_eq!(r.get("a.x"), 5);
        assert_eq!(r.get("a.y"), 2);
        assert_eq!(r.get("missing"), 0);
    }

    #[test]
    fn hist_log2_buckets() {
        let mut h = Hist::default();
        for v in [0u64, 1, 2, 3, 4, 7, 8] {
            h.observe(v);
        }
        // bucket 0: {0}; 1: {1}; 2: {2,3}; 3: {4..7}; 4: {8..15}
        assert_eq!(h.buckets, vec![1, 1, 2, 2, 1]);
        assert_eq!(h.count, 7);
        assert_eq!(h.sum, 25);
    }

    #[test]
    fn render_is_insertion_order_independent() {
        let mut a = Registry::new();
        a.add("z.last", 1);
        a.add("a.first", 2);
        a.observe("h.two", 3);
        a.observe("h.one", 1);
        let mut b = Registry::new();
        b.observe("h.one", 1);
        b.add("a.first", 2);
        b.observe("h.two", 3);
        b.add("z.last", 1);
        assert_eq!(a.to_json().render(), b.to_json().render());
        let js = a.to_json().render();
        assert!(js.contains("\"a.first\":2"), "{js}");
        assert!(js.contains("\"h.two\""), "{js}");
    }
}
