//! Chrome trace-event export: the [`Tracer`]'s spans/instants rendered
//! as the JSON object format `chrome://tracing` and Perfetto load
//! (`{"traceEvents":[...]}`), via the hand-rolled `util::json` — no
//! serde, no dependencies.
//!
//! Layout: pid 0, one tid per [`Track`] (0 = run, 1 = server,
//! `2+2i` = device-i compute, `3+2i` = device-i NIC), named by `"M"`
//! metadata events.  Spans expand to `B`/`E` pairs, instants to `i`;
//! the global order is a total sort on `(ts, tid, phase, index)` with
//! `E` before `B` at equal timestamps so back-to-back spans close
//! before the next opens — per track the file is monotone in `ts` and
//! every prefix has at least as many `B` as `E` ([`check_well_formed`]).
//! A registry snapshot rides along under a top-level `"metrics"` key
//! (Perfetto ignores unknown keys).

use super::{Ev, EvKind, Registry, Track, Tracer};
use crate::util::json::Json;

/// One rendered trace-event row.
#[derive(Debug, Clone, PartialEq)]
pub struct ChromeEvent {
    pub name: &'static str,
    /// `'B'` | `'E'` | `'i'` | `'M'`.
    pub ph: char,
    /// Microseconds.
    pub ts: f64,
    pub tid: usize,
    pub args: Option<Json>,
}

fn tid(track: Track) -> usize {
    match track {
        Track::Run => 0,
        Track::Server => 1,
        Track::Device(i) => 2 + 2 * i,
        Track::Net(i) => 3 + 2 * i,
    }
}

fn track_label(t: usize) -> String {
    match t {
        0 => "run".into(),
        1 => "server".into(),
        t if t % 2 == 0 => format!("device-{}", (t - 2) / 2),
        t => format!("net-{}", (t - 3) / 2),
    }
}

fn args_of(kind: &EvKind) -> Json {
    match *kind {
        EvKind::Task { task, client } => Json::obj().set("task", task).set("client", client),
        EvKind::TaskAborted { task } => Json::obj().set("task", task),
        EvKind::StateLoad { clients } => Json::obj().set("clients", clients),
        EvKind::CommDown { task, bytes } | EvKind::CommUp { task, bytes } => {
            Json::obj().set("task", task).set("bytes", Json::Int(bytes as i64))
        }
        EvKind::Tail { bytes, cross_bytes, group_aggs } => Json::obj()
            .set("bytes", Json::Int(bytes as i64))
            .set("cross_bytes", Json::Int(cross_bytes as i64))
            .set("group_aggs", group_aggs),
        EvKind::StateFlush { bytes } => Json::obj().set("bytes", Json::Int(bytes as i64)),
        EvKind::Flush { flush, applied, stale } => {
            Json::obj().set("flush", flush).set("applied", applied).set("stale", stale)
        }
        EvKind::Sched { round, placed } => {
            Json::obj().set("round", round).set("placed", placed)
        }
        EvKind::Round { round } => Json::obj().set("round", round),
        EvKind::DeviceLeave { device } | EvKind::DeviceJoin { device } => {
            Json::obj().set("device", device)
        }
        EvKind::ShardTransfer { worker, bytes } => {
            Json::obj().set("worker", worker).set("bytes", Json::Int(bytes as i64))
        }
    }
}

fn phase_rank(ph: char) -> u8 {
    // E before B at equal (ts, tid): a span that ends exactly where the
    // next begins closes first, keeping every prefix B-balanced.
    match ph {
        'E' => 0,
        'B' => 1,
        _ => 2,
    }
}

/// Expand the tracer's events into the sorted rendered row sequence
/// (metadata first, then the totally ordered timeline).
pub fn expand(tracer: &Tracer) -> Vec<ChromeEvent> {
    let mut rows: Vec<ChromeEvent> = Vec::with_capacity(2 * tracer.events.len());
    for e in &tracer.events {
        let Ev { t0, t1, track, ref kind, .. } = *e;
        let t = tid(track);
        if t1 > t0 {
            rows.push(ChromeEvent {
                name: kind.name(),
                ph: 'B',
                ts: t0 * 1e6,
                tid: t,
                args: Some(args_of(kind)),
            });
            rows.push(ChromeEvent { name: kind.name(), ph: 'E', ts: t1 * 1e6, tid: t, args: None });
        } else {
            rows.push(ChromeEvent {
                name: kind.name(),
                ph: 'i',
                ts: t0 * 1e6,
                tid: t,
                args: Some(args_of(kind)),
            });
        }
    }
    // Total order: the index tiebreak makes the sort a pure function of
    // the tracer's (already deterministic) event sequence.
    let mut order: Vec<usize> = (0..rows.len()).collect();
    order.sort_by(|&a, &b| {
        rows[a]
            .ts
            .total_cmp(&rows[b].ts)
            .then(rows[a].tid.cmp(&rows[b].tid))
            .then(phase_rank(rows[a].ph).cmp(&phase_rank(rows[b].ph)))
            .then(a.cmp(&b))
    });
    let mut sorted: Vec<ChromeEvent> = order.into_iter().map(|i| rows[i].clone()).collect();

    // Thread-name metadata, one per distinct tid, ahead of the timeline.
    let mut tids: Vec<usize> = sorted.iter().map(|r| r.tid).collect();
    tids.sort_unstable();
    tids.dedup();
    let mut out: Vec<ChromeEvent> = tids
        .into_iter()
        .map(|t| ChromeEvent {
            name: "thread_name",
            ph: 'M',
            ts: 0.0,
            tid: t,
            args: Some(Json::obj().set("name", track_label(t))),
        })
        .collect();
    out.append(&mut sorted);
    out
}

fn row_json(r: &ChromeEvent) -> Json {
    let mut j = Json::obj()
        .set("name", r.name)
        .set("ph", r.ph.to_string())
        .set("ts", Json::Num(r.ts))
        .set("pid", 0usize)
        .set("tid", r.tid);
    if r.ph == 'i' {
        j = j.set("s", "t"); // thread-scoped instant
    }
    if let Some(a) = &r.args {
        j = j.set("args", a.clone());
    }
    j
}

/// Render rows (+ optional registry snapshot) to the final file bytes.
pub fn render_events(rows: &[ChromeEvent], metrics: Option<&Registry>) -> String {
    let mut top = Json::obj()
        .set("traceEvents", Json::Arr(rows.iter().map(row_json).collect()))
        .set("displayTimeUnit", "ms");
    if let Some(reg) = metrics {
        top = top.set("metrics", reg.to_json());
    }
    top.render()
}

/// Expand + render in one call.
pub fn render(tracer: &Tracer, metrics: Option<&Registry>) -> String {
    render_events(&expand(tracer), metrics)
}

/// Structural invariants of an expanded row sequence: per track the
/// timeline is monotone non-decreasing in `ts`, every `E` closes an
/// open `B`, and every track ends balanced.  Returns a description of
/// the first violation.
pub fn check_well_formed(rows: &[ChromeEvent]) -> Result<(), String> {
    // Per-tid (last_ts, open span depth), dense-indexed.
    let max_tid = rows.iter().map(|r| r.tid).max().unwrap_or(0);
    let mut last_ts = vec![f64::NEG_INFINITY; max_tid + 1];
    let mut depth = vec![0i64; max_tid + 1];
    for (i, r) in rows.iter().enumerate() {
        if r.ph == 'M' {
            continue;
        }
        if r.ts < last_ts[r.tid] {
            return Err(format!(
                "row {i}: ts {} went backwards on tid {} (last {})",
                r.ts, r.tid, last_ts[r.tid]
            ));
        }
        last_ts[r.tid] = r.ts;
        match r.ph {
            'B' => depth[r.tid] += 1,
            'E' => {
                depth[r.tid] -= 1;
                if depth[r.tid] < 0 {
                    return Err(format!("row {i}: E without open B on tid {}", r.tid));
                }
            }
            'i' => {}
            ph => return Err(format!("row {i}: unknown phase {ph:?}")),
        }
    }
    for (t, d) in depth.iter().enumerate() {
        if *d != 0 {
            return Err(format!("tid {t}: {d} span(s) left open"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_tracer() -> Tracer {
        let mut t = Tracer::new();
        t.span(0.0, 4.0, Track::Run, EvKind::Round { round: 0 });
        t.span(0.0, 2.0, Track::Device(0), EvKind::Task { task: 0, client: 5 });
        // Back-to-back spans sharing an endpoint on one track.
        t.span(2.0, 3.0, Track::Device(0), EvKind::Task { task: 1, client: 6 });
        t.span(2.0, 2.5, Track::Net(0), EvKind::CommUp { task: 0, bytes: 128 });
        t.instant(3.0, Track::Server, EvKind::DeviceLeave { device: 1 });
        t.span(3.0, 4.0, Track::Server, EvKind::Tail {
            bytes: 256,
            cross_bytes: 64,
            group_aggs: 2,
        });
        t
    }

    #[test]
    fn expand_is_well_formed_and_e_precedes_b_at_shared_endpoints() {
        let rows = expand(&demo_tracer());
        check_well_formed(&rows).unwrap();
        // device-0: task#0's E at ts=2e6 must precede task#1's B at 2e6.
        let d0: Vec<&ChromeEvent> =
            rows.iter().filter(|r| r.tid == 2 && r.ph != 'M').collect();
        let ends: Vec<usize> =
            d0.iter().enumerate().filter(|(_, r)| r.ph == 'E').map(|(i, _)| i).collect();
        let begins: Vec<usize> =
            d0.iter().enumerate().filter(|(_, r)| r.ph == 'B').map(|(i, _)| i).collect();
        assert_eq!(begins.len(), 2);
        assert_eq!(ends.len(), 2);
        assert!(ends[0] < begins[1], "E(2.0) must sort before B(2.0): {d0:?}");
    }

    #[test]
    fn render_produces_loadable_json_with_metadata_and_metrics() {
        let mut reg = Registry::new();
        reg.add("engine.tasks", 2);
        let s = render(&demo_tracer(), Some(&reg));
        assert!(s.starts_with("{\"traceEvents\":["), "{s}");
        assert!(s.contains("\"ph\":\"M\""), "{s}");
        assert!(s.contains("\"thread_name\""), "{s}");
        assert!(s.contains("\"device-0\""), "{s}");
        assert!(s.contains("\"s\":\"t\""), "{s}");
        assert!(s.contains("\"metrics\":{"), "{s}");
        assert!(s.contains("\"engine.tasks\":2"), "{s}");
    }

    #[test]
    fn check_rejects_unbalanced_and_backwards_rows() {
        let open = vec![ChromeEvent { name: "task", ph: 'B', ts: 0.0, tid: 2, args: None }];
        assert!(check_well_formed(&open).is_err());
        let back = vec![
            ChromeEvent { name: "a", ph: 'i', ts: 5.0, tid: 0, args: None },
            ChromeEvent { name: "b", ph: 'i', ts: 4.0, tid: 0, args: None },
        ];
        assert!(check_well_formed(&back).is_err());
        let stray = vec![ChromeEvent { name: "task", ph: 'E', ts: 0.0, tid: 2, args: None }];
        assert!(check_well_formed(&stray).is_err());
    }
}
