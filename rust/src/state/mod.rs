//! Client state manager (paper §3.4): disk-backed storage for stateful
//! FL algorithms (SCAFFOLD control variates, FedDyn h-terms, ...).
//!
//! The memory math of Table 1 depends on exactly this component: with M
//! clients of state size s_d, holding everything in RAM costs O(s_d·M);
//! the manager keeps at most a configurable budget in an LRU cache
//! (O(s_d·K) in practice — each device touches one client at a time) and
//! spills the rest to disk (O(s_d·M) disk, the irreducible term).
//!
//! Writes are atomic (tmp + rename) so a crashed simulation never leaves
//! a torn snapshot.  All traffic is counted — the Table-1/Table-3
//! harnesses read these counters.

use crate::model::ParamSet;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Traffic counters (read by the complexity harnesses).
#[derive(Debug, Default, Clone, Copy)]
pub struct StateMetrics {
    pub loads: u64,
    pub saves: u64,
    pub cache_hits: u64,
    pub disk_reads: u64,
    pub disk_writes: u64,
    pub bytes_written: u64,
    pub bytes_read: u64,
    /// High-water mark of cache residency in bytes (the O(s_d·K) term).
    pub peak_cache_bytes: u64,
}

/// Disk-backed client-state store with a bounded LRU cache.
pub struct StateManager {
    dir: PathBuf,
    cache_budget: usize,
    cache: HashMap<u64, (Vec<u8>, u64)>, // id -> (bytes, last-use tick)
    cache_bytes: usize,
    tick: u64,
    pub metrics: StateMetrics,
}

impl StateManager {
    /// `cache_budget` caps in-memory state bytes; 0 disables caching
    /// (every access hits disk — the SP-with-state-manager column).
    pub fn new(dir: impl AsRef<Path>, cache_budget: usize) -> Result<StateManager> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating state dir {}", dir.display()))?;
        Ok(StateManager {
            dir,
            cache_budget,
            cache: HashMap::new(),
            cache_bytes: 0,
            tick: 0,
            metrics: StateMetrics::default(),
        })
    }

    fn path(&self, client: u64) -> PathBuf {
        self.dir.join(format!("client_{client}.state"))
    }

    fn touch(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    fn cache_insert(&mut self, client: u64, bytes: Vec<u8>) {
        if self.cache_budget == 0 {
            return;
        }
        let sz = bytes.len();
        // A value that can never fit must bypass the cache entirely —
        // the old path evicted every resident entry first and then
        // skipped the insertion anyway, churning the whole cache for
        // nothing.  Only drop a stale same-key copy so reads can't
        // return the previous value from cache.
        if sz > self.cache_budget {
            if let Some((old, _)) = self.cache.remove(&client) {
                self.cache_bytes -= old.len();
            }
            return;
        }
        // Replacing the same key: release its bytes before budgeting so
        // eviction never counts the old copy against the new one.
        if let Some((old, _)) = self.cache.remove(&client) {
            self.cache_bytes -= old.len();
        }
        // Evict least-recently-used until the new value fits.
        while self.cache_bytes + sz > self.cache_budget && !self.cache.is_empty() {
            let (&old, _) = self
                .cache
                .iter()
                .min_by_key(|(_, (_, tick))| *tick)
                .expect("non-empty cache");
            if let Some((b, _)) = self.cache.remove(&old) {
                self.cache_bytes -= b.len();
            }
        }
        let t = self.touch();
        self.cache.insert(client, (bytes, t));
        self.cache_bytes += sz;
        self.metrics.peak_cache_bytes =
            self.metrics.peak_cache_bytes.max(self.cache_bytes as u64);
    }

    /// `Save_State(m, S)` (Alg. 2): persist to disk, refresh cache.
    pub fn save(&mut self, client: u64, bytes: &[u8]) -> Result<()> {
        self.metrics.saves += 1;
        let tmp = self.dir.join(format!(".client_{client}.tmp"));
        std::fs::write(&tmp, bytes).with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, self.path(client)).context("atomic rename")?;
        self.metrics.disk_writes += 1;
        self.metrics.bytes_written += bytes.len() as u64;
        self.cache_insert(client, bytes.to_vec());
        Ok(())
    }

    /// `Load_State(m)` (Alg. 2): cache first, then disk; None when the
    /// client has no state yet (first round it is selected).
    pub fn load(&mut self, client: u64) -> Result<Option<Vec<u8>>> {
        self.metrics.loads += 1;
        if let Some((bytes, _)) = self.cache.get(&client) {
            let out = bytes.clone();
            self.metrics.cache_hits += 1;
            let t = self.touch();
            self.cache.get_mut(&client).unwrap().1 = t;
            return Ok(Some(out));
        }
        let p = self.path(client);
        if !p.exists() {
            return Ok(None);
        }
        let bytes = std::fs::read(&p).with_context(|| format!("reading {}", p.display()))?;
        self.metrics.disk_reads += 1;
        self.metrics.bytes_read += bytes.len() as u64;
        self.cache_insert(client, bytes.clone());
        Ok(Some(bytes))
    }

    /// Typed convenience: ParamSet state (covers SCAFFOLD c_i / FedDyn h_i).
    pub fn save_params(&mut self, client: u64, p: &ParamSet) -> Result<()> {
        self.save(client, &p.to_bytes())
    }

    pub fn load_params(&mut self, client: u64) -> Result<Option<ParamSet>> {
        match self.load(client)? {
            None => Ok(None),
            Some(b) => Ok(Some(ParamSet::from_bytes(&b)?)),
        }
    }

    /// Bytes currently on disk across all clients (Table-1 disk column).
    pub fn disk_bytes(&self) -> Result<u64> {
        let mut total = 0;
        for e in std::fs::read_dir(&self.dir)? {
            let e = e?;
            if e.file_name().to_string_lossy().ends_with(".state") {
                total += e.metadata()?.len();
            }
        }
        Ok(total)
    }

    pub fn cache_resident_bytes(&self) -> usize {
        self.cache_bytes
    }

    /// Wipe everything (between experiments): disk, cache, *and* the
    /// traffic counters + LRU clock — a reused manager must start the
    /// next experiment with a clean slate, or the Table-1 harnesses
    /// report the previous run's traffic in the next run's columns.
    pub fn clear(&mut self) -> Result<()> {
        for e in std::fs::read_dir(&self.dir)? {
            let p = e?.path();
            if p.extension().map(|x| x == "state").unwrap_or(false)
                || p.file_name()
                    .map(|n| n.to_string_lossy().ends_with(".tmp"))
                    .unwrap_or(false)
            {
                std::fs::remove_file(p)?;
            }
        }
        self.cache.clear();
        self.cache_bytes = 0;
        self.tick = 0;
        self.metrics = StateMetrics::default();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("parrot_state_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn save_load_round_trip() {
        let mut sm = StateManager::new(tmp_dir("rt"), 1 << 20).unwrap();
        assert!(sm.load(7).unwrap().is_none());
        sm.save(7, b"hello state").unwrap();
        assert_eq!(sm.load(7).unwrap().unwrap(), b"hello state");
        // first load was a miss-from-cache? save populated cache -> hit
        assert!(sm.metrics.cache_hits >= 1);
    }

    #[test]
    fn params_round_trip() {
        let mut sm = StateManager::new(tmp_dir("params"), 1 << 20).unwrap();
        let p = ParamSet::init_he(&[vec![10, 4], vec![4]], 3);
        sm.save_params(42, &p).unwrap();
        assert_eq!(sm.load_params(42).unwrap().unwrap(), p);
    }

    #[test]
    fn survives_cold_cache() {
        let dir = tmp_dir("cold");
        {
            let mut sm = StateManager::new(&dir, 1 << 20).unwrap();
            sm.save(1, b"persisted").unwrap();
        }
        // New manager, empty cache: must read from disk.
        let mut sm2 = StateManager::new(&dir, 1 << 20).unwrap();
        assert_eq!(sm2.load(1).unwrap().unwrap(), b"persisted");
        assert_eq!(sm2.metrics.disk_reads, 1);
    }

    #[test]
    fn lru_eviction_respects_budget() {
        let mut sm = StateManager::new(tmp_dir("lru"), 100).unwrap();
        sm.save(1, &[1u8; 40]).unwrap();
        sm.save(2, &[2u8; 40]).unwrap();
        assert_eq!(sm.cache_resident_bytes(), 80);
        sm.save(3, &[3u8; 40]).unwrap(); // evicts client 1
        assert!(sm.cache_resident_bytes() <= 100);
        // client 1 now needs disk
        let before = sm.metrics.disk_reads;
        assert_eq!(sm.load(1).unwrap().unwrap(), vec![1u8; 40]);
        assert_eq!(sm.metrics.disk_reads, before + 1);
    }

    #[test]
    fn lru_order_is_recency() {
        let mut sm = StateManager::new(tmp_dir("recency"), 100).unwrap();
        sm.save(1, &[1u8; 40]).unwrap();
        sm.save(2, &[2u8; 40]).unwrap();
        sm.load(1).unwrap(); // refresh 1; 2 becomes LRU
        sm.save(3, &[3u8; 40]).unwrap(); // should evict 2, not 1
        let before = sm.metrics.disk_reads;
        sm.load(1).unwrap();
        assert_eq!(sm.metrics.disk_reads, before, "1 must still be cached");
        sm.load(2).unwrap();
        assert_eq!(sm.metrics.disk_reads, before + 1, "2 must have been evicted");
    }

    #[test]
    fn zero_budget_disables_cache() {
        let mut sm = StateManager::new(tmp_dir("zero"), 0).unwrap();
        sm.save(1, b"x").unwrap();
        sm.load(1).unwrap();
        assert_eq!(sm.metrics.cache_hits, 0);
        assert_eq!(sm.metrics.disk_reads, 1);
        assert_eq!(sm.cache_resident_bytes(), 0);
    }

    #[test]
    fn disk_bytes_counts_all_clients() {
        let mut sm = StateManager::new(tmp_dir("disk"), 1 << 20).unwrap();
        sm.clear().unwrap();
        sm.save(1, &[0u8; 100]).unwrap();
        sm.save(2, &[0u8; 250]).unwrap();
        assert_eq!(sm.disk_bytes().unwrap(), 350);
        sm.save(1, &[0u8; 50]).unwrap(); // overwrite shrinks
        assert_eq!(sm.disk_bytes().unwrap(), 300);
    }

    #[test]
    fn overwrite_updates_value() {
        let mut sm = StateManager::new(tmp_dir("ow"), 1 << 20).unwrap();
        sm.save(5, b"v1").unwrap();
        sm.save(5, b"v2").unwrap();
        assert_eq!(sm.load(5).unwrap().unwrap(), b"v2");
    }

    #[test]
    fn oversized_value_bypasses_cache_but_persists() {
        let mut sm = StateManager::new(tmp_dir("big"), 10).unwrap();
        sm.save(9, &[7u8; 100]).unwrap();
        assert_eq!(sm.cache_resident_bytes(), 0);
        assert_eq!(sm.load(9).unwrap().unwrap(), vec![7u8; 100]);
    }

    #[test]
    fn oversized_insert_does_not_evict_residents() {
        let mut sm = StateManager::new(tmp_dir("big_noevict"), 100).unwrap();
        sm.save(1, &[1u8; 40]).unwrap();
        sm.save(2, &[2u8; 40]).unwrap();
        assert_eq!(sm.cache_resident_bytes(), 80);
        // An oversized value must not churn out clients 1 and 2.
        sm.save(3, &[3u8; 500]).unwrap();
        assert_eq!(sm.cache_resident_bytes(), 80, "residents must survive");
        let before = sm.metrics.disk_reads;
        sm.load(1).unwrap();
        sm.load(2).unwrap();
        assert_eq!(sm.metrics.disk_reads, before, "1 and 2 must still be cached");
        assert_eq!(sm.metrics.peak_cache_bytes, 80);
    }

    #[test]
    fn same_key_reinsertion_accounting_is_exact() {
        let mut sm = StateManager::new(tmp_dir("rekey"), 100).unwrap();
        sm.save(1, &[0u8; 60]).unwrap();
        assert_eq!(sm.cache_resident_bytes(), 60);
        // Same key, same size: no double count, no eviction churn.
        sm.save(1, &[1u8; 60]).unwrap();
        assert_eq!(sm.cache_resident_bytes(), 60);
        assert_eq!(sm.metrics.peak_cache_bytes, 60, "no transient 120-byte residency");
        assert_eq!(sm.load(1).unwrap().unwrap(), vec![1u8; 60]);
    }

    #[test]
    fn same_key_growth_releases_old_copy_before_evicting_neighbors() {
        let mut sm = StateManager::new(tmp_dir("rekey_grow"), 100).unwrap();
        sm.save(1, &[1u8; 30]).unwrap(); // LRU-to-be
        sm.save(2, &[2u8; 40]).unwrap();
        // Growing client 2 to 50 fits once its own 40 bytes are
        // released (30 + 50 = 80); the old path budgeted 70 + 50 and
        // evicted innocent client 1 first.
        sm.save(2, &[2u8; 50]).unwrap();
        assert_eq!(sm.cache_resident_bytes(), 80);
        let before = sm.metrics.disk_reads;
        sm.load(1).unwrap();
        assert_eq!(sm.metrics.disk_reads, before, "client 1 must not be evicted");
        assert_eq!(sm.load(2).unwrap().unwrap(), vec![2u8; 50]);
        // Same key growing past the whole budget: the stale cached copy
        // must not linger (a read would resurrect the old value).
        sm.save(2, &[9u8; 500]).unwrap();
        assert_eq!(sm.load(2).unwrap().unwrap(), vec![9u8; 500]);
        assert_eq!(sm.cache_resident_bytes(), 30, "only client 1 remains resident");
        assert_eq!(sm.metrics.peak_cache_bytes, 80);
    }

    #[test]
    fn clear_removes_files_and_cache() {
        let mut sm = StateManager::new(tmp_dir("clear"), 1 << 20).unwrap();
        sm.save(1, b"a").unwrap();
        sm.clear().unwrap();
        assert_eq!(sm.disk_bytes().unwrap(), 0);
        assert!(sm.load(1).unwrap().is_none());
    }

    #[test]
    fn clear_resets_metrics_and_lru_clock() {
        // Regression: clear() used to keep the previous experiment's
        // counters and tick, so a reused manager misreported the next
        // Table-1 run's traffic and recency.
        let mut sm = StateManager::new(tmp_dir("clear_metrics"), 1 << 20).unwrap();
        sm.save(1, &[1u8; 64]).unwrap();
        sm.save(2, &[2u8; 64]).unwrap();
        sm.load(1).unwrap();
        sm.load(9).unwrap(); // miss
        assert!(sm.metrics.saves == 2 && sm.metrics.loads == 2);
        assert!(sm.metrics.peak_cache_bytes == 128 && sm.metrics.bytes_written == 128);

        sm.clear().unwrap();
        let m = sm.metrics;
        assert_eq!(
            (m.loads, m.saves, m.cache_hits, m.disk_reads, m.disk_writes),
            (0, 0, 0, 0, 0)
        );
        assert_eq!((m.bytes_written, m.bytes_read, m.peak_cache_bytes), (0, 0, 0));

        // The next experiment's counters start from zero and the LRU
        // clock restarts without resurrecting stale recency.
        sm.save(3, &[3u8; 32]).unwrap();
        sm.load(3).unwrap();
        assert_eq!(sm.metrics.saves, 1);
        assert_eq!(sm.metrics.loads, 1);
        assert_eq!(sm.metrics.cache_hits, 1);
        assert_eq!(sm.metrics.peak_cache_bytes, 32);
    }
}
