//! Client state manager (paper §3.4): disk-backed storage for stateful
//! FL algorithms (SCAFFOLD control variates, FedDyn h-terms, ...).
//!
//! The memory math of Table 1 depends on exactly this component: with M
//! clients of state size s_d, holding everything in RAM costs O(s_d·M);
//! the manager keeps at most a configurable budget in an LRU cache
//! (O(s_d·K) in practice — each device touches one client at a time) and
//! spills the rest to disk (O(s_d·M) disk, the irreducible term).
//!
//! The cache tier is the shared
//! [`WriteBackCache`](crate::statestore::WriteBackCache) (O(log n)
//! eviction — the old per-eviction `min_by_key` scan made tight-budget
//! rotations O(n²); `benches/bench_state.rs` pins the fix at 10k
//! clients).  Two persistence modes:
//!
//! - **write-through** (default, the seed behavior): every save lands
//!   on disk immediately.
//! - **write-back** (`with_write_back(true)`): saves only dirty the
//!   cache; disk is paid on eviction of a dirty entry and at explicit
//!   [`StateManager::flush`] (round boundary / shutdown).  A client
//!   re-trained while cache-resident stops paying a disk write per
//!   save — counted in [`StateMetrics::avoided_writes`].
//!
//! Writes are atomic (tmp + rename) so a crashed simulation never leaves
//! a torn snapshot.  All traffic is counted — the Table-1/Table-3
//! harnesses read these counters.  `disk_bytes()` is O(1): the running
//! total is maintained by save/flush/clear (primed by one directory
//! walk at construction) and asserted against a fresh walk in tests.

use crate::model::ParamSet;
use crate::statestore::WriteBackCache;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Traffic counters (read by the complexity harnesses).
#[derive(Debug, Default, Clone, Copy)]
pub struct StateMetrics {
    pub loads: u64,
    pub saves: u64,
    pub cache_hits: u64,
    pub disk_reads: u64,
    pub disk_writes: u64,
    pub bytes_written: u64,
    pub bytes_read: u64,
    /// High-water mark of cache residency in bytes (the O(s_d·K) term).
    pub peak_cache_bytes: u64,
    /// Write-back only: saves absorbed by an already-dirty cache entry —
    /// disk writes the write-through path would have paid.
    pub avoided_writes: u64,
}

/// Disk-backed client-state store with a bounded write-back LRU cache.
pub struct StateManager {
    dir: PathBuf,
    write_back: bool,
    cache: WriteBackCache<Vec<u8>>,
    /// Per-client on-disk sizes written by THIS manager (plus whatever
    /// the constructor's walk found) — backs the O(1) `disk_bytes`.
    on_disk: HashMap<u64, u64>,
    disk_total: u64,
    pub metrics: StateMetrics,
}

impl StateManager {
    /// `cache_budget` caps in-memory state bytes; 0 disables caching
    /// (every access hits disk — the SP-with-state-manager column).
    /// Starts in write-through mode; see [`StateManager::with_write_back`].
    pub fn new(dir: impl AsRef<Path>, cache_budget: usize) -> Result<StateManager> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating state dir {}", dir.display()))?;
        let mut sm = StateManager {
            dir,
            write_back: false,
            cache: WriteBackCache::new(cache_budget),
            on_disk: HashMap::new(),
            disk_total: 0,
            metrics: StateMetrics::default(),
        };
        // Prime the running disk total from whatever a previous run (or
        // another manager over the same directory) left behind.
        for e in std::fs::read_dir(&sm.dir)? {
            let e = e?;
            let name = e.file_name().to_string_lossy().into_owned();
            if let Some(id) = name.strip_prefix("client_").and_then(|s| s.strip_suffix(".state"))
            {
                if let Ok(client) = id.parse::<u64>() {
                    let sz = e.metadata()?.len();
                    sm.on_disk.insert(client, sz);
                    sm.disk_total += sz;
                }
            }
        }
        Ok(sm)
    }

    /// Switch persistence mode (builder-style; write-through default).
    pub fn with_write_back(mut self, on: bool) -> StateManager {
        self.write_back = on;
        self
    }

    pub fn is_write_back(&self) -> bool {
        self.write_back
    }

    fn path(&self, client: u64) -> PathBuf {
        self.dir.join(format!("client_{client}.state"))
    }

    /// Atomic disk write + size/traffic bookkeeping.
    fn write_file(&mut self, client: u64, bytes: &[u8]) -> Result<()> {
        let tmp = self.dir.join(format!(".client_{client}.tmp"));
        std::fs::write(&tmp, bytes).with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, self.path(client)).context("atomic rename")?;
        self.metrics.disk_writes += 1;
        self.metrics.bytes_written += bytes.len() as u64;
        let sz = bytes.len() as u64;
        if let Some(old) = self.on_disk.insert(client, sz) {
            self.disk_total -= old;
        }
        self.disk_total += sz;
        Ok(())
    }

    /// Persist entries the cache displaced (write-back contract: dirty
    /// evictions must spill or their data dies with the cache).
    fn spill_evicted(&mut self, evicted: Vec<crate::statestore::Evicted<Vec<u8>>>) -> Result<()> {
        for e in evicted {
            if e.dirty {
                self.write_file(e.client, &e.value)?;
            }
        }
        Ok(())
    }

    fn note_peak(&mut self) {
        self.metrics.peak_cache_bytes =
            self.metrics.peak_cache_bytes.max(self.cache.resident_bytes() as u64);
    }

    /// `Save_State(m, S)` (Alg. 2).  Write-through: persist + refresh
    /// cache.  Write-back: dirty the cache; disk is deferred to
    /// eviction or [`StateManager::flush`] (values the cache rejects —
    /// zero budget, oversized — fall back to an immediate write so
    /// durability never depends on residency).
    pub fn save(&mut self, client: u64, bytes: &[u8]) -> Result<()> {
        self.metrics.saves += 1;
        if self.write_back {
            if self.cache.is_dirty(client) {
                self.metrics.avoided_writes += 1;
            }
            let (resident, evicted) = self.cache.insert(client, bytes.to_vec(), true);
            self.spill_evicted(evicted)?;
            if !resident {
                self.write_file(client, bytes)?;
            }
        } else {
            self.write_file(client, bytes)?;
            let (_, evicted) = self.cache.insert(client, bytes.to_vec(), false);
            self.spill_evicted(evicted)?;
        }
        self.note_peak();
        Ok(())
    }

    /// `Load_State(m)` (Alg. 2): cache first (which may be dirty —
    /// newer than disk), then disk; None when the client has no state
    /// yet (first round it is selected).
    pub fn load(&mut self, client: u64) -> Result<Option<Vec<u8>>> {
        self.metrics.loads += 1;
        if let Some(bytes) = self.cache.get(client) {
            let out = bytes.clone();
            self.metrics.cache_hits += 1;
            return Ok(Some(out));
        }
        let p = self.path(client);
        if !p.exists() {
            return Ok(None);
        }
        let bytes = std::fs::read(&p).with_context(|| format!("reading {}", p.display()))?;
        self.metrics.disk_reads += 1;
        self.metrics.bytes_read += bytes.len() as u64;
        let (_, evicted) = self.cache.insert(client, bytes.clone(), false);
        self.spill_evicted(evicted)?;
        self.note_peak();
        Ok(Some(bytes))
    }

    /// Write every dirty cache entry to disk (round boundary /
    /// shutdown consistency point).  Returns the number of entries
    /// flushed; a no-op in write-through mode.
    pub fn flush(&mut self) -> Result<usize> {
        let ids = self.cache.dirty_ids();
        let n = ids.len();
        for c in ids {
            let bytes = self.cache.peek(c).expect("dirty entry present").clone();
            self.write_file(c, &bytes)?;
            self.cache.mark_clean(c);
        }
        Ok(n)
    }

    /// Dirty (not-yet-persisted) cache entries.
    pub fn dirty_count(&self) -> usize {
        self.cache.dirty_ids().len()
    }

    /// Typed convenience: ParamSet state (covers SCAFFOLD c_i / FedDyn h_i).
    pub fn save_params(&mut self, client: u64, p: &ParamSet) -> Result<()> {
        self.save(client, &p.to_bytes()?)
    }

    pub fn load_params(&mut self, client: u64) -> Result<Option<ParamSet>> {
        match self.load(client)? {
            None => Ok(None),
            Some(b) => Ok(Some(ParamSet::from_bytes(&b)?)),
        }
    }

    /// Bytes currently on disk across all clients (Table-1 disk
    /// column).  O(1): running total maintained by save/flush/clear —
    /// `disk_bytes_walk` is the audited slow path.
    pub fn disk_bytes(&self) -> u64 {
        self.disk_total
    }

    /// The old full directory walk; tests assert it always equals the
    /// cached total for a single-manager directory.
    pub fn disk_bytes_walk(&self) -> Result<u64> {
        let mut total = 0;
        for e in std::fs::read_dir(&self.dir)? {
            let e = e?;
            if e.file_name().to_string_lossy().ends_with(".state") {
                total += e.metadata()?.len();
            }
        }
        Ok(total)
    }

    pub fn cache_resident_bytes(&self) -> usize {
        self.cache.resident_bytes()
    }

    /// Wipe everything (between experiments): disk, cache, the running
    /// disk total, *and* the traffic counters + LRU clock — a reused
    /// manager must start the next experiment with a clean slate, or
    /// the Table-1 harnesses report the previous run's traffic in the
    /// next run's columns.
    pub fn clear(&mut self) -> Result<()> {
        for e in std::fs::read_dir(&self.dir)? {
            let p = e?.path();
            if p.extension().map(|x| x == "state").unwrap_or(false)
                || p.file_name()
                    .map(|n| n.to_string_lossy().ends_with(".tmp"))
                    .unwrap_or(false)
            {
                std::fs::remove_file(p)?;
            }
        }
        self.cache.clear();
        self.on_disk.clear();
        self.disk_total = 0;
        self.metrics = StateMetrics::default();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("parrot_state_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn save_load_round_trip() {
        let mut sm = StateManager::new(tmp_dir("rt"), 1 << 20).unwrap();
        assert!(sm.load(7).unwrap().is_none());
        sm.save(7, b"hello state").unwrap();
        assert_eq!(sm.load(7).unwrap().unwrap(), b"hello state");
        // first load was a miss-from-cache? save populated cache -> hit
        assert!(sm.metrics.cache_hits >= 1);
    }

    #[test]
    fn params_round_trip() {
        let mut sm = StateManager::new(tmp_dir("params"), 1 << 20).unwrap();
        let p = ParamSet::init_he(&[vec![10, 4], vec![4]], 3);
        sm.save_params(42, &p).unwrap();
        assert_eq!(sm.load_params(42).unwrap().unwrap(), p);
    }

    #[test]
    fn survives_cold_cache() {
        let dir = tmp_dir("cold");
        {
            let mut sm = StateManager::new(&dir, 1 << 20).unwrap();
            sm.save(1, b"persisted").unwrap();
        }
        // New manager, empty cache: must read from disk.
        let mut sm2 = StateManager::new(&dir, 1 << 20).unwrap();
        assert_eq!(sm2.load(1).unwrap().unwrap(), b"persisted");
        assert_eq!(sm2.metrics.disk_reads, 1);
        // The constructor's walk primed the running total too.
        assert_eq!(sm2.disk_bytes(), 9);
    }

    #[test]
    fn lru_eviction_respects_budget() {
        let mut sm = StateManager::new(tmp_dir("lru"), 100).unwrap();
        sm.save(1, &[1u8; 40]).unwrap();
        sm.save(2, &[2u8; 40]).unwrap();
        assert_eq!(sm.cache_resident_bytes(), 80);
        sm.save(3, &[3u8; 40]).unwrap(); // evicts client 1
        assert!(sm.cache_resident_bytes() <= 100);
        // client 1 now needs disk
        let before = sm.metrics.disk_reads;
        assert_eq!(sm.load(1).unwrap().unwrap(), vec![1u8; 40]);
        assert_eq!(sm.metrics.disk_reads, before + 1);
    }

    #[test]
    fn lru_order_is_recency() {
        let mut sm = StateManager::new(tmp_dir("recency"), 100).unwrap();
        sm.save(1, &[1u8; 40]).unwrap();
        sm.save(2, &[2u8; 40]).unwrap();
        sm.load(1).unwrap(); // refresh 1; 2 becomes LRU
        sm.save(3, &[3u8; 40]).unwrap(); // should evict 2, not 1
        let before = sm.metrics.disk_reads;
        sm.load(1).unwrap();
        assert_eq!(sm.metrics.disk_reads, before, "1 must still be cached");
        sm.load(2).unwrap();
        assert_eq!(sm.metrics.disk_reads, before + 1, "2 must have been evicted");
    }

    #[test]
    fn zero_budget_disables_cache() {
        let mut sm = StateManager::new(tmp_dir("zero"), 0).unwrap();
        sm.save(1, b"x").unwrap();
        sm.load(1).unwrap();
        assert_eq!(sm.metrics.cache_hits, 0);
        assert_eq!(sm.metrics.disk_reads, 1);
        assert_eq!(sm.cache_resident_bytes(), 0);
    }

    #[test]
    fn disk_bytes_counts_all_clients() {
        let mut sm = StateManager::new(tmp_dir("disk"), 1 << 20).unwrap();
        sm.clear().unwrap();
        sm.save(1, &[0u8; 100]).unwrap();
        sm.save(2, &[0u8; 250]).unwrap();
        assert_eq!(sm.disk_bytes(), 350);
        sm.save(1, &[0u8; 50]).unwrap(); // overwrite shrinks
        assert_eq!(sm.disk_bytes(), 300);
    }

    #[test]
    fn cached_disk_total_always_equals_fresh_walk() {
        // Regression (satellite): disk_bytes used to walk the directory
        // on every call; the O(1) running total must stay in lock-step
        // with the filesystem through saves, overwrites (grow and
        // shrink), write-back flushes, and clear().
        let mut sm = StateManager::new(tmp_dir("disk_cached"), 200).unwrap();
        let check = |sm: &StateManager| {
            assert_eq!(sm.disk_bytes(), sm.disk_bytes_walk().unwrap());
        };
        check(&sm);
        sm.save(1, &[0u8; 100]).unwrap();
        sm.save(2, &[0u8; 60]).unwrap();
        check(&sm);
        sm.save(1, &[0u8; 10]).unwrap(); // shrink
        sm.save(2, &[0u8; 150]).unwrap(); // grow
        check(&sm);
        let mut wb = StateManager::new(tmp_dir("disk_cached_wb"), 500)
            .unwrap()
            .with_write_back(true);
        wb.save(1, &[0u8; 100]).unwrap();
        assert_eq!(wb.disk_bytes(), 0, "write-back defers");
        assert_eq!(wb.disk_bytes(), wb.disk_bytes_walk().unwrap());
        wb.flush().unwrap();
        assert_eq!(wb.disk_bytes(), 100);
        assert_eq!(wb.disk_bytes(), wb.disk_bytes_walk().unwrap());
        wb.clear().unwrap();
        assert_eq!(wb.disk_bytes(), 0);
        assert_eq!(wb.disk_bytes(), wb.disk_bytes_walk().unwrap());
    }

    #[test]
    fn overwrite_updates_value() {
        let mut sm = StateManager::new(tmp_dir("ow"), 1 << 20).unwrap();
        sm.save(5, b"v1").unwrap();
        sm.save(5, b"v2").unwrap();
        assert_eq!(sm.load(5).unwrap().unwrap(), b"v2");
    }

    #[test]
    fn oversized_value_bypasses_cache_but_persists() {
        let mut sm = StateManager::new(tmp_dir("big"), 10).unwrap();
        sm.save(9, &[7u8; 100]).unwrap();
        assert_eq!(sm.cache_resident_bytes(), 0);
        assert_eq!(sm.load(9).unwrap().unwrap(), vec![7u8; 100]);
    }

    #[test]
    fn oversized_insert_does_not_evict_residents() {
        let mut sm = StateManager::new(tmp_dir("big_noevict"), 100).unwrap();
        sm.save(1, &[1u8; 40]).unwrap();
        sm.save(2, &[2u8; 40]).unwrap();
        assert_eq!(sm.cache_resident_bytes(), 80);
        // An oversized value must not churn out clients 1 and 2.
        sm.save(3, &[3u8; 500]).unwrap();
        assert_eq!(sm.cache_resident_bytes(), 80, "residents must survive");
        let before = sm.metrics.disk_reads;
        sm.load(1).unwrap();
        sm.load(2).unwrap();
        assert_eq!(sm.metrics.disk_reads, before, "1 and 2 must still be cached");
        assert_eq!(sm.metrics.peak_cache_bytes, 80);
    }

    #[test]
    fn same_key_reinsertion_accounting_is_exact() {
        let mut sm = StateManager::new(tmp_dir("rekey"), 100).unwrap();
        sm.save(1, &[0u8; 60]).unwrap();
        assert_eq!(sm.cache_resident_bytes(), 60);
        // Same key, same size: no double count, no eviction churn.
        sm.save(1, &[1u8; 60]).unwrap();
        assert_eq!(sm.cache_resident_bytes(), 60);
        assert_eq!(sm.metrics.peak_cache_bytes, 60, "no transient 120-byte residency");
        assert_eq!(sm.load(1).unwrap().unwrap(), vec![1u8; 60]);
    }

    #[test]
    fn same_key_growth_releases_old_copy_before_evicting_neighbors() {
        let mut sm = StateManager::new(tmp_dir("rekey_grow"), 100).unwrap();
        sm.save(1, &[1u8; 30]).unwrap(); // LRU-to-be
        sm.save(2, &[2u8; 40]).unwrap();
        // Growing client 2 to 50 fits once its own 40 bytes are
        // released (30 + 50 = 80); the old path budgeted 70 + 50 and
        // evicted innocent client 1 first.
        sm.save(2, &[2u8; 50]).unwrap();
        assert_eq!(sm.cache_resident_bytes(), 80);
        let before = sm.metrics.disk_reads;
        sm.load(1).unwrap();
        assert_eq!(sm.metrics.disk_reads, before, "client 1 must not be evicted");
        assert_eq!(sm.load(2).unwrap().unwrap(), vec![2u8; 50]);
        // Same key growing past the whole budget: the stale cached copy
        // must not linger (a read would resurrect the old value).
        sm.save(2, &[9u8; 500]).unwrap();
        assert_eq!(sm.load(2).unwrap().unwrap(), vec![9u8; 500]);
        assert_eq!(sm.cache_resident_bytes(), 30, "only client 1 remains resident");
        assert_eq!(sm.metrics.peak_cache_bytes, 80);
    }

    #[test]
    fn write_back_defers_and_coalesces_disk_writes() {
        // Regression (satellite): save() used to write through
        // unconditionally — a client re-trained while cache-resident
        // paid a disk write per save.  Write-back coalesces them into
        // one write at the explicit flush.
        let mut sm = StateManager::new(tmp_dir("wb"), 1 << 16)
            .unwrap()
            .with_write_back(true);
        sm.save(1, &[1u8; 64]).unwrap();
        sm.save(1, &[2u8; 64]).unwrap();
        sm.save(1, &[3u8; 64]).unwrap();
        assert_eq!(sm.metrics.disk_writes, 0, "no write until flush");
        assert_eq!(sm.metrics.avoided_writes, 2, "two saves coalesced");
        assert_eq!(sm.dirty_count(), 1);
        // Reads see the newest (dirty) data, not stale disk.
        assert_eq!(sm.load(1).unwrap().unwrap(), vec![3u8; 64]);
        assert_eq!(sm.flush().unwrap(), 1);
        assert_eq!(sm.metrics.disk_writes, 1);
        assert_eq!(sm.metrics.bytes_written, 64);
        assert_eq!(sm.dirty_count(), 0);
        assert_eq!(sm.disk_bytes(), 64);
        // Second flush is a no-op.
        assert_eq!(sm.flush().unwrap(), 0);
        assert_eq!(sm.metrics.disk_writes, 1);
    }

    #[test]
    fn write_back_spills_dirty_evictions() {
        let mut sm = StateManager::new(tmp_dir("wb_spill"), 100)
            .unwrap()
            .with_write_back(true);
        sm.save(1, &[1u8; 60]).unwrap();
        sm.save(2, &[2u8; 60]).unwrap(); // evicts dirty client 1 -> spill
        assert_eq!(sm.metrics.disk_writes, 1, "dirty eviction must hit disk");
        // Cold read of the spilled client returns the spilled data.
        assert_eq!(sm.load(1).unwrap().unwrap(), vec![1u8; 60]);
        assert_eq!(sm.metrics.disk_reads, 1);
    }

    #[test]
    fn write_back_durability_survives_a_cold_restart_after_flush() {
        let dir = tmp_dir("wb_cold");
        {
            let mut sm = StateManager::new(&dir, 1 << 16).unwrap().with_write_back(true);
            sm.save(4, b"newest").unwrap();
            sm.flush().unwrap();
        }
        let mut sm2 = StateManager::new(&dir, 1 << 16).unwrap();
        assert_eq!(sm2.load(4).unwrap().unwrap(), b"newest");
    }

    #[test]
    fn write_back_oversized_values_still_persist_immediately() {
        let mut sm = StateManager::new(tmp_dir("wb_big"), 10)
            .unwrap()
            .with_write_back(true);
        sm.save(9, &[7u8; 100]).unwrap();
        assert_eq!(sm.metrics.disk_writes, 1, "non-resident saves write through");
        assert_eq!(sm.load(9).unwrap().unwrap(), vec![7u8; 100]);
    }

    #[test]
    fn clear_removes_files_and_cache() {
        let mut sm = StateManager::new(tmp_dir("clear"), 1 << 20).unwrap();
        sm.save(1, b"a").unwrap();
        sm.clear().unwrap();
        assert_eq!(sm.disk_bytes(), 0);
        assert_eq!(sm.disk_bytes_walk().unwrap(), 0);
        assert!(sm.load(1).unwrap().is_none());
    }

    #[test]
    fn clear_resets_metrics_and_lru_clock() {
        // Regression: clear() used to keep the previous experiment's
        // counters and tick, so a reused manager misreported the next
        // Table-1 run's traffic and recency.
        let mut sm = StateManager::new(tmp_dir("clear_metrics"), 1 << 20).unwrap();
        sm.save(1, &[1u8; 64]).unwrap();
        sm.save(2, &[2u8; 64]).unwrap();
        sm.load(1).unwrap();
        sm.load(9).unwrap(); // miss
        assert!(sm.metrics.saves == 2 && sm.metrics.loads == 2);
        assert!(sm.metrics.peak_cache_bytes == 128 && sm.metrics.bytes_written == 128);

        sm.clear().unwrap();
        let m = sm.metrics;
        assert_eq!(
            (m.loads, m.saves, m.cache_hits, m.disk_reads, m.disk_writes),
            (0, 0, 0, 0, 0)
        );
        assert_eq!((m.bytes_written, m.bytes_read, m.peak_cache_bytes), (0, 0, 0));
        assert_eq!(m.avoided_writes, 0);

        // The next experiment's counters start from zero and the LRU
        // clock restarts without resurrecting stale recency.
        sm.save(3, &[3u8; 32]).unwrap();
        sm.load(3).unwrap();
        assert_eq!(sm.metrics.saves, 1);
        assert_eq!(sm.metrics.loads, 1);
        assert_eq!(sm.metrics.cache_hits, 1);
        assert_eq!(sm.metrics.peak_cache_bytes, 32);
    }
}
