//! Per-fn effect summaries and their fixpoint over the call graph.
//!
//! Seeds come from the same token patterns as the per-file rules
//! (holds-Hash*, ambient-entropy, panicking, float-fold), attributed
//! to the innermost enclosing fn.  A reverse BFS per effect bit then
//! closes them over [`super::callgraph`]: a fn *has* an effect if any
//! resolvable callee has it.  Unresolved calls contribute nothing —
//! they are reported separately (conservative-unknown), so a missing
//! edge can hide an effect but never fabricate one.
//!
//! The three transitive rules fire on the *call edge* that crosses
//! the policy boundary — the strict module's call into effectful
//! non-strict code (or the decode path's call into panicking
//! non-decode code) — with the witness chain down to the seed line in
//! the message.  Edges between two strict files are not re-flagged:
//! the seed itself is already a direct finding there.

use super::callgraph::{CallGraph, SourceFile};
use super::rules::{self, Finding};

pub const HOLDS_HASH: u8 = 1 << 0;
pub const AMBIENT_ENTROPY: u8 = 1 << 1;
pub const PANICKING: u8 = 1 << 2;
pub const FLOAT_FOLD: u8 = 1 << 3;

const BITS: [u8; 4] = [HOLDS_HASH, AMBIENT_ENTROPY, PANICKING, FLOAT_FOLD];

fn bit_index(bit: u8) -> usize {
    BITS.iter().position(|&b| b == bit).expect("known effect bit")
}

pub struct Effects {
    /// Directly seeded bits per fn.
    pub seeds: Vec<u8>,
    /// Seeds closed over the call graph.
    pub closure: Vec<u8>,
    /// First line that seeded each bit, per fn.
    seed_line: Vec<[Option<usize>; 4]>,
    /// For a propagated bit: the call (index into `cg.calls`) one hop
    /// toward the seed — enough to reconstruct the whole chain.
    witness: Vec<[Option<usize>; 4]>,
}

fn line_seeds(line: &str) -> u8 {
    let mut bits = 0u8;
    if rules::word_in(line, "HashMap") || rules::word_in(line, "HashSet") {
        bits |= HOLDS_HASH;
    }
    if rules::ENTROPY_PATTERNS.iter().any(|p| line.contains(p)) {
        bits |= AMBIENT_ENTROPY;
    }
    if rules::PANIC_PATTERNS.iter().any(|p| line.contains(p)) {
        bits |= PANICKING;
    }
    if rules::FLOAT_ACCUM_PATTERNS.iter().any(|p| line.contains(p)) {
        bits |= FLOAT_FOLD;
    }
    bits
}

/// Seed effect bits from non-test lines and propagate to a fixpoint.
pub fn compute(cg: &CallGraph, files: &[SourceFile]) -> Effects {
    let n = cg.fns.len();
    let mut seeds = vec![0u8; n];
    let mut seed_line = vec![[None; 4]; n];
    for (file_idx, sf) in files.iter().enumerate() {
        for (i, line) in sf.map.lines.iter().enumerate() {
            let ln = i + 1;
            if sf.map.line_is_test(ln) {
                continue;
            }
            let Some(fid) = cg.line_fn[file_idx][i] else { continue };
            let bits = line_seeds(line);
            if bits == 0 {
                continue;
            }
            seeds[fid] |= bits;
            for (bi, &bit) in BITS.iter().enumerate() {
                if bits & bit != 0 && seed_line[fid][bi].is_none() {
                    seed_line[fid][bi] = Some(ln);
                }
            }
        }
    }

    // Reverse adjacency: callee -> call indexes targeting it.
    let mut rev: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (ci, c) in cg.calls.iter().enumerate() {
        rev[c.callee].push(ci);
    }

    let mut closure = seeds.clone();
    let mut witness = vec![[None; 4]; n];
    for (bi, &bit) in BITS.iter().enumerate() {
        let mut queue: Vec<usize> =
            (0..n).filter(|&f| seeds[f] & bit != 0).collect();
        while let Some(f) = queue.pop() {
            for &ci in &rev[f] {
                let caller = cg.calls[ci].caller;
                if closure[caller] & bit == 0 {
                    closure[caller] |= bit;
                    witness[caller][bi] = Some(ci);
                    queue.push(caller);
                }
            }
        }
    }
    Effects { seeds, closure, seed_line, witness }
}

impl Effects {
    /// Human-readable chain from the call at `site_ci` down to the
    /// seed of `bit`: `` `call` -> `call` -> seeded in `fn` (file:line) ``.
    pub fn chain(&self, cg: &CallGraph, site_ci: usize, bit: u8) -> String {
        let bi = bit_index(bit);
        let mut parts = vec![format!("`{}`", cg.calls[site_ci].text)];
        let mut cur = cg.calls[site_ci].callee;
        let mut hops = 0;
        while self.seeds[cur] & bit == 0 && hops < 64 {
            let Some(ci) = self.witness[cur][bi] else { break };
            parts.push(format!("`{}`", cg.calls[ci].text));
            cur = cg.calls[ci].callee;
            hops += 1;
        }
        let f = &cg.fns[cur];
        let ln = self.seed_line[cur][bi].unwrap_or(f.start);
        parts.push(format!("seeded in `{}` ({}:{})", f.name, f.file, ln));
        parts.join(" -> ")
    }
}

/// The three interprocedural rules.  Each fires on the boundary edge:
/// the callee carries the effect in its closure AND sits outside the
/// caller's policy scope (so the caller's own direct rules are blind
/// to it).
pub fn transitive_findings(
    cg: &CallGraph,
    fx: &Effects,
    files: &[SourceFile],
) -> Vec<Finding> {
    let decode_scope: Vec<Vec<bool>> =
        files.iter().map(|sf| rules::decode_scope(&sf.map)).collect();
    let fn_in_decode_scope = |fid: usize| -> bool {
        let f = &cg.fns[fid];
        decode_scope[f.file_idx]
            .get(f.start - 1)
            .copied()
            .unwrap_or(false)
    };

    let mut out = Vec::new();
    for (ci, c) in cg.calls.iter().enumerate() {
        let caller = &cg.fns[c.caller];
        let callee = &cg.fns[c.callee];
        if caller.is_test {
            continue;
        }
        let caller_strict = rules::STRICT_MODULES.contains(&rules::top_module(&caller.file));
        let callee_strict = rules::STRICT_MODULES.contains(&rules::top_module(&callee.file));

        if caller_strict && !callee_strict && fx.closure[c.callee] & HOLDS_HASH != 0 {
            out.push(Finding {
                rule: "unordered-iter-transitive",
                file: caller.file.clone(),
                line: c.line,
                message: format!(
                    "call from determinism-critical module `{}` reaches a Hash* \
                     container: {} — Hash* iteration order can leak into event/merge \
                     order through this helper; use an ordered view (BTreeMap/sorted \
                     snapshot) in the callee or keep the call out of the engine",
                    rules::top_module(&caller.file),
                    fx.chain(cg, ci, HOLDS_HASH),
                ),
            });
        }
        if caller_strict && !callee_strict && fx.closure[c.callee] & AMBIENT_ENTROPY != 0 {
            out.push(Finding {
                rule: "ambient-entropy-transitive",
                file: caller.file.clone(),
                line: c.line,
                message: format!(
                    "call from determinism-critical module `{}` reaches ambient \
                     entropy: {} — wallclock/OS entropy must be injected by the \
                     caller that consumes it (fn-pointer clock), not read beneath \
                     the engine",
                    rules::top_module(&caller.file),
                    fx.chain(cg, ci, AMBIENT_ENTROPY),
                ),
            });
        }
        let line_in_decode =
            decode_scope[caller.file_idx].get(c.line - 1).copied().unwrap_or(false);
        if line_in_decode
            && !fn_in_decode_scope(c.callee)
            && fx.closure[c.callee] & PANICKING != 0
        {
            out.push(Finding {
                rule: "panicking-decode-transitive",
                file: caller.file.clone(),
                line: c.line,
                message: format!(
                    "decode path calls a helper that can panic: {} — wire input is \
                     untrusted, so a hostile frame must surface as Err from the \
                     helper too, not a panic",
                    fx.chain(cg, ci, PANICKING),
                ),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::callgraph::CallGraph;
    use super::super::lexer::analyze_source;
    use super::*;

    fn sf(rel: &str, src: &str) -> SourceFile {
        SourceFile { rel: rel.to_string(), map: analyze_source(src) }
    }

    fn build(files: Vec<SourceFile>) -> (CallGraph, Effects, Vec<Finding>, Vec<SourceFile>) {
        let cg = CallGraph::build(&files);
        let fx = compute(&cg, &files);
        let findings = transitive_findings(&cg, &fx, &files);
        (cg, fx, findings, files)
    }

    #[test]
    fn entropy_propagates_through_two_hops_with_chain() {
        let (cg, fx, findings, _files) = build(vec![
            sf(
                "util/timer.rs",
                "pub fn wall_secs() -> f64 {\n    let t = std::time::Instant::now();\n    0.0\n}\n",
            ),
            sf("util/helpers.rs", "pub fn stamp() -> f64 {\n    crate::util::timer::wall_secs()\n}\n"),
            sf(
                "simulation/mod.rs",
                "pub fn round_started_at() -> f64 {\n    crate::util::helpers::stamp()\n}\n",
            ),
        ]);
        let stamp = cg.fns.iter().position(|f| f.name == "stamp").unwrap();
        assert_eq!(fx.seeds[stamp], 0);
        assert_eq!(fx.closure[stamp] & AMBIENT_ENTROPY, AMBIENT_ENTROPY);
        let hits: Vec<_> =
            findings.iter().filter(|f| f.rule == "ambient-entropy-transitive").collect();
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].file, "simulation/mod.rs");
        assert_eq!(hits[0].line, 2);
        assert!(hits[0].message.contains("`crate::util::helpers::stamp`"), "{}", hits[0].message);
        assert!(hits[0].message.contains("`crate::util::timer::wall_secs`"));
        assert!(hits[0].message.contains("(util/timer.rs:2)"));
    }

    #[test]
    fn hash_closure_flags_strict_caller_only_at_the_boundary() {
        let (_cg, _fx, findings, _files) = build(vec![
            sf(
                "util/helpers.rs",
                "use std::collections::HashMap;\npub fn tally() -> u64 {\n    let m: HashMap<u64, u64> = HashMap::new();\n    0\n}\n",
            ),
            sf("simulation/mod.rs", "pub fn cost() -> u64 {\n    crate::util::helpers::tally()\n}\n"),
            sf("exp/mod.rs", "pub fn report() -> u64 {\n    crate::util::helpers::tally()\n}\n"),
        ]);
        let hits: Vec<_> =
            findings.iter().filter(|f| f.rule == "unordered-iter-transitive").collect();
        assert_eq!(hits.len(), 1, "non-strict exp caller must not be flagged: {hits:?}");
        assert_eq!(hits[0].file, "simulation/mod.rs");
    }

    #[test]
    fn strict_to_strict_edges_are_not_reflagged() {
        // The Hash* seed inside a strict module is already a direct
        // `unordered-iter` finding; the transitive rule only reports
        // boundary crossings into non-strict code.
        let (_cg, _fx, findings, _files) = build(vec![
            sf(
                "scheduler/history.rs",
                "pub fn lookup() -> u64 {\n    let m: std::collections::HashMap<u64, u64> = Default::default();\n    0\n}\n",
            ),
            sf(
                "scheduler/mod.rs",
                "pub fn plan() -> u64 {\n    crate::scheduler::history::lookup()\n}\n",
            ),
        ]);
        assert!(findings.iter().all(|f| f.rule != "unordered-iter-transitive"), "{findings:?}");
    }

    #[test]
    fn panicking_helper_flagged_from_decode_scope_only() {
        let (_cg, _fx, findings, _files) = build(vec![sf(
            "compress/mod.rs",
            "fn halt(msg: &str) -> ! {\n    panic!(\"{msg}\")\n}\nfn check_tag(b: u8) {\n    halt(\"bad\");\n}\npub fn decode_guarded(dec: &mut Decoder) -> u8 {\n    check_tag(0);\n    0\n}\npub fn encode_guarded(enc: &mut Encoder) {\n    check_tag(0);\n}\n",
        )]);
        let hits: Vec<_> =
            findings.iter().filter(|f| f.rule == "panicking-decode-transitive").collect();
        assert_eq!(hits.len(), 1, "{findings:?}");
        assert_eq!(hits[0].line, 8, "only the decode-path call is flagged");
        assert!(hits[0].message.contains("`check_tag`"));
        assert!(hits[0].message.contains("`halt`"));
    }

    #[test]
    fn calls_between_decode_fns_are_exempt() {
        let (_cg, _fx, findings, _files) = build(vec![sf(
            "coordinator/messages.rs",
            "pub fn decode_inner(dec: &mut Decoder) -> u8 {\n    dec_next().unwrap()\n}\nfn dec_next() -> Option<u8> { None }\npub fn decode_outer(dec: &mut Decoder) -> u8 {\n    decode_inner(dec)\n}\n",
        )]);
        // decode_inner's own unwrap is the *direct* rule's business;
        // decode_outer -> decode_inner stays unflagged here.
        assert!(findings.iter().all(|f| f.rule != "panicking-decode-transitive"), "{findings:?}");
    }
}
