//! Whole-program call graph over the stripped source.
//!
//! `parrot lint` v1 was per-file: a strict module calling a `util`
//! helper that iterates a `HashMap` passed clean.  This module
//! recovers enough of the call structure — with zero external deps,
//! over the same stripped text the lexer produces — for the effect
//! propagation in [`super::effects`] to close that hole.
//!
//! Resolution is deliberately conservative and *honest* about its
//! limits (README "Effect propagation"):
//!
//!   * `Type::method(...)` resolves exactly against the crate-wide
//!     `(impl type, fn name)` index; `Self::m` uses the enclosing
//!     impl.  A method named on a crate impl type that does not exist
//!     is reported as unresolved, not ignored.
//!   * `module::free_fn(...)` resolves when exactly one free fn of
//!     that name lives in a file whose path mentions the qualifier.
//!   * bare `free_fn(...)` prefers the same file, then a unique
//!     crate-wide match.
//!   * `.method(...)` resolves only when exactly one crate fn of that
//!     name takes `self` AND the name is not a std-prelude-shaped
//!     name (`len`, `push`, `iter`, ...) that would mostly bind to
//!     std types.  Ambiguous receivers are reported as unresolved.
//!
//! Unresolved crate-like calls are surfaced as a summary (stderr +
//! [`CallGraph::unresolved`]), never as rule findings: they mark the
//! analysis boundary, not violations, so the baseline stays empty.
//!
//! Test lines produce no edges and test fns are not call targets:
//! effect propagation only cares about the shipped binary.

use super::lexer::SourceMap;
use std::collections::{BTreeMap, BTreeSet};

/// One scanned source file: path relative to the source root plus its
/// lexer analysis.  Loaded once in `analysis::run` and shared by the
/// token rules, the call graph, and the wire extractor.
pub struct SourceFile {
    pub rel: String,
    pub map: SourceMap,
}

/// One `fn` item in the whole-program index.
#[derive(Debug, Clone)]
pub struct FnNode {
    pub file_idx: usize,
    /// Source-root-relative path (duplicated for message rendering).
    pub file: String,
    pub name: String,
    /// Self type of the innermost enclosing `impl`, if any.
    pub owner: Option<String>,
    pub start: usize,
    pub end: usize,
    pub is_test: bool,
    pub has_self: bool,
}

/// A resolved call: `caller` fn invokes `callee` fn at `line`.
#[derive(Debug, Clone)]
pub struct CallSite {
    pub caller: usize,
    pub callee: usize,
    /// 1-based line in the caller's file.
    pub line: usize,
    /// Source text of the call path, e.g. `crate::util::timer::wall_secs`.
    pub text: String,
}

/// A call that looks crate-local but could not be pinned to one fn.
#[derive(Debug, Clone)]
pub struct Unresolved {
    pub file: String,
    pub line: usize,
    pub call: String,
    pub reason: &'static str,
}

pub struct CallGraph {
    pub fns: Vec<FnNode>,
    pub calls: Vec<CallSite>,
    pub unresolved: Vec<Unresolved>,
    /// Per file: 0-based line -> innermost enclosing fn id.
    pub line_fn: Vec<Vec<Option<usize>>>,
}

/// Method names shaped like std-prelude/container API: a `.name(` of
/// one of these overwhelmingly binds to std types, so treating the
/// lone crate fn of the same name as the target would fabricate
/// edges.  These are skipped silently (documented policy), everything
/// else ambiguous is *reported*.
const METHOD_BLOCKLIST: &[&str] = &[
    "abs", "all", "any", "as_bytes", "as_str", "borrow", "borrow_mut", "ceil", "chain", "clear",
    "clone", "cloned", "cmp", "collect", "contains", "contains_key", "copied", "count", "drain",
    "entry", "enumerate", "eq", "expect", "extend", "filter", "filter_map", "find", "first",
    "flat_map", "flatten", "floor", "flush", "fmt", "fold", "from", "get", "get_mut", "get_or",
    "hash", "index", "insert", "into_iter", "is_empty", "is_some", "is_none", "iter", "iter_mut",
    "join", "keys", "last", "len", "load", "lock", "map", "max", "mean", "min", "name", "new",
    "next", "parse", "pop", "position", "powf", "push", "read", "recv", "resize", "retain", "rev",
    "round", "run", "send", "snapshot", "sort", "sort_by", "sort_by_key", "split", "sqrt",
    "start", "finish", "store", "sum", "swap", "take", "to_string", "to_vec", "unwrap",
    "unwrap_or", "values", "write", "zip",
];

/// Idents that read like calls but are control flow / binding syntax.
const KEYWORDS: &[&str] = &[
    "as", "async", "await", "box", "break", "const", "continue", "crate", "dyn", "else", "enum",
    "fn", "for", "if", "impl", "in", "let", "loop", "match", "mod", "move", "mut", "pub", "ref",
    "return", "static", "struct", "super", "trait", "type", "union", "unsafe", "use", "where",
    "while",
];

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

/// One syntactic call candidate on a line: the `::`-separated path and
/// whether it was written as a `.method(` receiver call.
#[derive(Debug, PartialEq, Eq)]
pub(crate) struct CallCand {
    pub segs: Vec<String>,
    pub dotted: bool,
    /// `.method(` specifically on a literal `self.` receiver.
    pub recv_self: bool,
}

/// Extract call candidates from one stripped line.  Macros (`name!`)
/// and `fn` definitions are skipped; turbofish (`::<T>`) is skipped
/// inside paths.
pub(crate) fn scan_calls(line: &str) -> Vec<CallCand> {
    let b = line.as_bytes();
    let mut out = Vec::new();
    let mut prev_word = String::new();
    let mut i = 0usize;
    while i < b.len() {
        if !is_ident_start(b[i]) || (i > 0 && is_ident(b[i - 1])) {
            i += 1;
            continue;
        }
        let start = i;
        while i < b.len() && is_ident(b[i]) {
            i += 1;
        }
        let mut segs = vec![line[start..i].to_string()];
        loop {
            if i + 1 < b.len() && b[i] == b':' && b[i + 1] == b':' {
                let j = i + 2;
                if j < b.len() && b[j] == b'<' {
                    // turbofish: skip the balanced angle group
                    let mut depth = 1usize;
                    let mut k = j + 1;
                    while k < b.len() && depth > 0 {
                        match b[k] {
                            b'<' => depth += 1,
                            b'>' => depth -= 1,
                            _ => {}
                        }
                        k += 1;
                    }
                    i = k;
                    continue;
                }
                if j < b.len() && is_ident_start(b[j]) {
                    let mut k = j;
                    while k < b.len() && is_ident(b[k]) {
                        k += 1;
                    }
                    segs.push(line[j..k].to_string());
                    i = k;
                    continue;
                }
            }
            break;
        }
        let dotted = start > 0 && b[start - 1] == b'.';
        let recv_self = dotted && start >= 5 && &line[start - 5..start] == "self.";
        let mut k = i;
        while k < b.len() && b[k] == b' ' {
            k += 1;
        }
        let is_macro = k < b.len() && b[k] == b'!';
        let is_call = k < b.len() && b[k] == b'(';
        let this_word = segs.last().cloned().unwrap_or_default();
        if is_call && !is_macro && prev_word != "fn" {
            out.push(CallCand { segs, dotted, recv_self });
        }
        prev_word = this_word;
    }
    out
}

/// Directory components + file stem of a root-relative path:
/// `util/timer.rs` -> `["util", "timer"]`.
fn path_components(rel: &str) -> Vec<String> {
    let mut out = Vec::new();
    for (i, part) in rel.split('/').enumerate() {
        let is_last = i + 1 == rel.split('/').count();
        let p = if is_last { part.strip_suffix(".rs").unwrap_or(part) } else { part };
        if !p.is_empty() {
            out.push(p.to_string());
        }
    }
    out
}

/// Join enough leading lines of a fn span to cover its signature, and
/// report whether the first parameter group starts with `self`.
fn signature_has_self(map: &SourceMap, start: usize, end: usize) -> bool {
    let last = end.min(start + 9).min(map.lines.len());
    let sig: String = map.lines[start - 1..last].join(" ");
    let Some(open) = sig.find('(') else { return false };
    let rest = &sig[open + 1..];
    let stop = rest.find(&[',', ')'][..]).unwrap_or(rest.len());
    let first = rest[..stop].trim().trim_start_matches('&');
    let first = first.trim_start_matches("mut ").trim();
    // `'a self` / `self` / `mut self` / `self: ...`
    first == "self" || first.starts_with("self:") || first.ends_with(" self")
}

impl CallGraph {
    pub fn build(files: &[SourceFile]) -> CallGraph {
        // 1. fn index with owners and innermost-span line attribution.
        let mut fns: Vec<FnNode> = Vec::new();
        let mut line_fn: Vec<Vec<Option<usize>>> = Vec::new();
        for (file_idx, sf) in files.iter().enumerate() {
            let map = &sf.map;
            let mut per_line: Vec<Option<usize>> = vec![None; map.lines.len()];
            let mut span_len: Vec<usize> = vec![usize::MAX; map.lines.len()];
            for f in &map.fns {
                let owner = map
                    .impls
                    .iter()
                    .filter(|im| im.start <= f.start && f.start <= im.end)
                    .min_by_key(|im| im.end - im.start)
                    .map(|im| im.type_name.clone());
                let id = fns.len();
                fns.push(FnNode {
                    file_idx,
                    file: sf.rel.clone(),
                    name: f.name.clone(),
                    owner,
                    start: f.start,
                    end: f.end,
                    is_test: map.line_is_test(f.start),
                    has_self: signature_has_self(map, f.start, f.end),
                });
                let len = f.end - f.start;
                for l in f.start..=f.end.min(map.lines.len()) {
                    if len < span_len[l - 1] {
                        span_len[l - 1] = len;
                        per_line[l - 1] = Some(id);
                    }
                }
            }
            line_fn.push(per_line);
        }

        // 2. lookup indexes over non-test fns.
        let mut typed: BTreeMap<(String, String), Vec<usize>> = BTreeMap::new();
        let mut free: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        let mut methods: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        let mut impl_types: BTreeSet<String> = BTreeSet::new();
        for sf in files {
            for im in &sf.map.impls {
                impl_types.insert(im.type_name.clone());
            }
        }
        let mut module_names: BTreeSet<String> = BTreeSet::new();
        for sf in files {
            for c in path_components(&sf.rel) {
                module_names.insert(c);
            }
        }
        for (id, f) in fns.iter().enumerate() {
            if f.is_test {
                continue;
            }
            match &f.owner {
                Some(o) => {
                    typed.entry((o.clone(), f.name.clone())).or_default().push(id);
                }
                None => {
                    free.entry(f.name.clone()).or_default().push(id);
                }
            }
            if f.has_self {
                methods.entry(f.name.clone()).or_default().push(id);
            }
        }

        // 3. scan non-test lines and resolve.
        let mut calls: Vec<CallSite> = Vec::new();
        let mut unresolved: Vec<Unresolved> = Vec::new();
        for (file_idx, sf) in files.iter().enumerate() {
            let map = &sf.map;
            for (i, line) in map.lines.iter().enumerate() {
                let ln = i + 1;
                if map.line_is_test(ln) {
                    continue;
                }
                let Some(caller) = line_fn[file_idx][i] else { continue };
                if fns[caller].is_test {
                    continue;
                }
                for cand in scan_calls(line) {
                    resolve(
                        &cand, caller, file_idx, ln, &fns, &typed, &free, &methods, &impl_types,
                        &module_names, files, &mut calls, &mut unresolved,
                    );
                }
            }
        }
        CallGraph { fns, calls, unresolved, line_fn }
    }
}

#[allow(clippy::too_many_arguments)]
fn resolve(
    cand: &CallCand,
    caller: usize,
    file_idx: usize,
    line: usize,
    fns: &[FnNode],
    typed: &BTreeMap<(String, String), Vec<usize>>,
    free: &BTreeMap<String, Vec<usize>>,
    methods: &BTreeMap<String, Vec<usize>>,
    impl_types: &BTreeSet<String>,
    module_names: &BTreeSet<String>,
    files: &[SourceFile],
    calls: &mut Vec<CallSite>,
    unresolved: &mut Vec<Unresolved>,
) {
    let name = cand.segs.last().expect("candidate has a segment").clone();
    // Uppercase-initial last segment: tuple-struct / enum-variant
    // constructor (`Some(`, `Slot::Collected(`) — not a fn call.
    if name.chars().next().is_some_and(|c| c.is_ascii_uppercase()) {
        return;
    }
    let text = if cand.dotted && cand.segs.len() == 1 {
        format!(".{name}")
    } else {
        cand.segs.join("::")
    };
    let file = files[file_idx].rel.clone();
    fn push_edges(
        ids: &[usize],
        caller: usize,
        line: usize,
        text: &str,
        calls: &mut Vec<CallSite>,
    ) {
        for &id in ids {
            calls.push(CallSite { caller, callee: id, line, text: text.to_string() });
        }
    }

    if cand.segs.len() == 1 && cand.dotted {
        // `.method(` — receiver type unknown.  A literal `self.`
        // receiver resolves exactly through the enclosing impl.
        if cand.recv_self {
            if let Some(owner) = fns[caller].owner.clone() {
                if let Some(ids) = typed.get(&(owner, name.clone())) {
                    push_edges(ids, caller, line, &text, calls);
                    return;
                }
            }
        }
        if METHOD_BLOCKLIST.contains(&name.as_str()) {
            return;
        }
        match methods.get(&name).map(|v| v.as_slice()).unwrap_or(&[]) {
            [] => {}
            [one] => push_edges(&[*one], caller, line, &text, calls),
            _ => unresolved.push(Unresolved {
                file,
                line,
                call: text,
                reason: "method name defined on several crate types; receiver unknown",
            }),
        }
        return;
    }

    if cand.segs.len() == 1 {
        // bare `free_fn(` — same file first, then unique crate-wide.
        if KEYWORDS.contains(&name.as_str()) || name == "self" {
            return;
        }
        let all = free.get(&name).map(|v| v.as_slice()).unwrap_or(&[]);
        let same: Vec<usize> =
            all.iter().copied().filter(|&id| fns[id].file_idx == file_idx).collect();
        if !same.is_empty() {
            push_edges(&same, caller, line, &text, calls);
            return;
        }
        match all {
            [] => {}
            [one] => push_edges(&[*one], caller, line, &text, calls),
            _ => unresolved.push(Unresolved {
                file,
                line,
                call: text,
                reason: "free fn name defined in several modules; no qualifier",
            }),
        }
        return;
    }

    let qual = cand.segs[cand.segs.len() - 2].clone();
    if qual == "Self" || qual.chars().next().is_some_and(|c| c.is_ascii_uppercase()) {
        // `Type::method(` / `Self::method(`
        let owner = if qual == "Self" { fns[caller].owner.clone() } else { Some(qual.clone()) };
        let Some(owner) = owner else { return };
        if let Some(ids) = typed.get(&(owner.clone(), name.clone())) {
            push_edges(ids, caller, line, &text, calls);
        } else if impl_types.contains(&owner) {
            unresolved.push(Unresolved {
                file,
                line,
                call: text,
                reason: "no such method on this crate impl type (trait/derive method?)",
            });
        }
        return;
    }

    // `module::free_fn(` — match the qualifier against path components.
    let all = free.get(&name).map(|v| v.as_slice()).unwrap_or(&[]);
    let by_module: Vec<usize> = if ["crate", "self", "super"].contains(&qual.as_str()) {
        all.to_vec()
    } else {
        all.iter()
            .copied()
            .filter(|&id| path_components(&fns[id].file).iter().any(|c| *c == qual))
            .collect()
    };
    match by_module.as_slice() {
        [one] => push_edges(&[*one], caller, line, &text, calls),
        [] => {
            let crate_like = cand
                .segs
                .iter()
                .any(|s| ["crate", "self", "super"].contains(&s.as_str()) || module_names.contains(s));
            if crate_like {
                unresolved.push(Unresolved {
                    file,
                    line,
                    call: text,
                    reason: "crate-flavored path does not resolve to a known free fn",
                });
            }
        }
        _ => unresolved.push(Unresolved {
            file,
            line,
            call: text,
            reason: "qualifier matches several free fns",
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::super::lexer::analyze_source;
    use super::*;

    fn sf(rel: &str, src: &str) -> SourceFile {
        SourceFile { rel: rel.to_string(), map: analyze_source(src) }
    }

    fn find_fn<'a>(cg: &'a CallGraph, name: &str) -> &'a FnNode {
        cg.fns.iter().find(|f| f.name == name).unwrap()
    }

    fn edges<'a>(cg: &'a CallGraph, caller: &str) -> Vec<&'a str> {
        let cid =
            cg.fns.iter().position(|f| f.name == caller).expect("caller indexed");
        cg.calls
            .iter()
            .filter(|c| c.caller == cid)
            .map(|c| cg.fns[c.callee].name.as_str())
            .collect()
    }

    #[test]
    fn scan_finds_paths_methods_and_skips_macros() {
        let cands = scan_calls("    let x = crate::util::timer::wall_secs() + helper(y);");
        assert_eq!(cands.len(), 2);
        assert_eq!(cands[0].segs, vec!["crate", "util", "timer", "wall_secs"]);
        assert!(!cands[0].dotted);
        assert_eq!(cands[1].segs, vec!["helper"]);
        assert!(scan_calls("    bail!(\"nope\"); format!(\"x\");").is_empty());
        let dotted = scan_calls("    let n = xs.iter().sum::<f64>();");
        assert!(dotted.iter().all(|c| c.dotted));
        assert_eq!(dotted[1].segs, vec!["sum"], "turbofish skipped: {dotted:?}");
    }

    #[test]
    fn fn_definitions_are_not_call_sites() {
        assert!(scan_calls("pub fn schedule_from(devices: &[u64]) -> Plan {").is_empty());
        assert!(scan_calls("    fn decl(&self) -> usize;").is_empty());
    }

    #[test]
    fn typed_and_module_calls_resolve_exactly() {
        let files = vec![
            sf(
                "util/timer.rs",
                "pub struct Stopwatch;\nimpl Stopwatch {\n    pub fn start() -> Self { Stopwatch }\n}\npub fn wall_secs() -> f64 { 0.0 }\n",
            ),
            sf(
                "scheduler/mod.rs",
                "pub fn plan() {\n    let sw = crate::util::timer::Stopwatch::start();\n    let t = crate::util::timer::wall_secs();\n}\n",
            ),
        ];
        let cg = CallGraph::build(&files);
        assert_eq!(edges(&cg, "plan"), vec!["start", "wall_secs"]);
        assert!(cg.unresolved.is_empty(), "{:?}", cg.unresolved);
        assert_eq!(find_fn(&cg, "start").owner.as_deref(), Some("Stopwatch"));
        assert!(!find_fn(&cg, "start").has_self);
    }

    #[test]
    fn bare_calls_prefer_same_file_then_unique_global() {
        let files = vec![
            sf("a/mod.rs", "fn helper() {}\npub fn go() { helper(); solo(); }\n"),
            sf("b/mod.rs", "fn helper() {}\npub fn solo() {}\n"),
        ];
        let cg = CallGraph::build(&files);
        let e = edges(&cg, "go");
        assert_eq!(e, vec!["helper", "solo"]);
        let helper_edge = &cg.calls[0];
        assert_eq!(cg.fns[helper_edge.callee].file, "a/mod.rs", "same-file fn wins");
    }

    #[test]
    fn ambiguous_dot_methods_are_reported_not_linked() {
        let files = vec![
            sf("a/mod.rs", "pub struct A;\nimpl A {\n    pub fn touch(&self) {}\n}\n"),
            sf("b/mod.rs", "pub struct B;\nimpl B {\n    pub fn touch(&self) {}\n}\n"),
            sf("c/mod.rs", "pub fn go(x: &X) {\n    x.touch();\n}\n"),
        ];
        let cg = CallGraph::build(&files);
        assert!(cg.calls.is_empty());
        assert_eq!(cg.unresolved.len(), 1);
        assert_eq!(cg.unresolved[0].call, ".touch");
    }

    #[test]
    fn unique_dot_method_links_unless_blocklisted() {
        let files = vec![
            sf("a/mod.rs", "pub struct A;\nimpl A {\n    pub fn touch(&self) {}\n    pub fn len(&self) -> usize { 0 }\n}\n"),
            sf("c/mod.rs", "pub fn go(x: &A) {\n    x.touch();\n    x.len();\n}\n"),
        ];
        let cg = CallGraph::build(&files);
        assert_eq!(edges(&cg, "go"), vec!["touch"], "`.len(` is prelude-shaped, skipped");
        assert!(cg.unresolved.is_empty());
    }

    #[test]
    fn self_methods_resolve_through_the_enclosing_impl() {
        let files = vec![sf(
            "a/mod.rs",
            "pub struct A;\nimpl A {\n    pub fn inner(&self) {}\n    pub fn outer(&self) { self.inner(); }\n}\npub struct B;\nimpl B {\n    pub fn inner(&self) {}\n}\n",
        )];
        let cg = CallGraph::build(&files);
        let outer = cg.fns.iter().position(|f| f.name == "outer").unwrap();
        let call = cg.calls.iter().find(|c| c.caller == outer).unwrap();
        assert_eq!(cg.fns[call.callee].owner.as_deref(), Some("A"));
        assert!(cg.unresolved.is_empty(), "self. resolves despite two `inner`s");
    }

    #[test]
    fn unknown_method_on_crate_type_is_reported() {
        let files = vec![
            sf("a/mod.rs", "pub struct A;\nimpl A {\n    pub fn real(&self) {}\n}\n"),
            sf("c/mod.rs", "pub fn go() {\n    A::imagined();\n    String::from_utf8(v);\n}\n"),
        ];
        let cg = CallGraph::build(&files);
        assert_eq!(cg.unresolved.len(), 1, "{:?}", cg.unresolved);
        assert_eq!(cg.unresolved[0].call, "A::imagined");
    }

    #[test]
    fn test_code_neither_calls_nor_is_called() {
        let files = vec![sf(
            "a/mod.rs",
            "pub fn live() { helper(); }\nfn helper() {}\n#[cfg(test)]\nmod tests {\n    fn helper() {}\n    #[test]\n    fn t() { super::live(); helper(); }\n}\n",
        )];
        let cg = CallGraph::build(&files);
        assert_eq!(cg.calls.len(), 1);
        assert_eq!(cg.fns[cg.calls[0].callee].name, "helper");
        assert!(!cg.fns[cg.calls[0].callee].is_test);
    }
}
