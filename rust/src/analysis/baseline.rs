//! Baseline ratchet for `parrot lint`.
//!
//! The committed `lint.baseline` grandfathers pre-existing findings as
//! `(rule, file) -> count` entries.  The ratchet only turns one way:
//!
//!   * actual > baseline  — new debt; the run FAILS,
//!   * actual < baseline  — debt paid down; the run passes but warns
//!     so the baseline gets tightened (`--write-baseline`),
//!   * a baseline entry whose file has no findings at all is stale and
//!     also warns.
//!
//! Counts (not line numbers) key the ratchet so unrelated edits that
//! shift lines don't churn the committed file.

use super::rules::Finding;
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// `(rule, file) -> grandfathered count`, ordered for stable renders.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct Baseline {
    pub entries: BTreeMap<(String, String), usize>,
}

impl Baseline {
    /// Parse the committed format: one `rule file count` triple per
    /// line, `#` comments and blank lines ignored.
    pub fn parse(text: &str) -> Result<Baseline> {
        let mut entries = BTreeMap::new();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let (rule, file, count) = match (it.next(), it.next(), it.next(), it.next()) {
                (Some(r), Some(f), Some(c), None) => (r, f, c),
                _ => bail!("lint baseline line {}: expected `rule file count`, got {raw:?}", i + 1),
            };
            let count: usize = count
                .parse()
                .map_err(|_| anyhow::anyhow!("lint baseline line {}: bad count {count:?}", i + 1))?;
            if count == 0 {
                bail!("lint baseline line {}: zero-count entry is noise — delete it", i + 1);
            }
            entries.insert((rule.to_string(), file.to_string()), count);
        }
        Ok(Baseline { entries })
    }

    /// Reject entries naming rules the analyzer doesn't emit — a
    /// typo'd or removed rule name would otherwise grandfather
    /// nothing while looking like it does.
    pub fn validate_rules(&self, known: &[&str]) -> Result<()> {
        for (rule, file) in self.entries.keys() {
            if !known.contains(&rule.as_str()) {
                bail!(
                    "lint baseline: unknown rule {rule:?} (entry for {file}) — \
                     known rules: {}",
                    known.join(", ")
                );
            }
        }
        Ok(())
    }

    /// Render findings back into the committed format (the
    /// `--write-baseline` path).
    pub fn render(findings: &[Finding]) -> String {
        let counts = count_by_group(findings);
        let mut out = String::from(
            "# parrot lint baseline — grandfathered findings, keyed (rule, file, count).\n\
             # The ratchet only goes down: counts may shrink, never grow.\n\
             # Regenerate (after deliberately paying debt down) with:\n\
             #   parrot lint --write-baseline\n",
        );
        for ((rule, file), n) in &counts {
            out.push_str(&format!("{rule} {file} {n}\n"));
        }
        out
    }
}

/// Findings grouped to baseline keys.
pub fn count_by_group(findings: &[Finding]) -> BTreeMap<(String, String), usize> {
    let mut counts: BTreeMap<(String, String), usize> = BTreeMap::new();
    for f in findings {
        *counts.entry((f.rule.to_string(), f.file.clone())).or_insert(0) += 1;
    }
    counts
}

/// Outcome of resolving a run against the baseline.
#[derive(Debug, Default)]
pub struct Resolution {
    /// Findings in groups that exceed their grandfathered count —
    /// these fail the run.  The whole offending group is listed (the
    /// analyzer cannot know which of N+1 findings is "the new one").
    pub violations: Vec<Finding>,
    /// `(rule, file, baseline, actual)` where actual < baseline:
    /// tighten the committed file.
    pub slack: Vec<(String, String, usize, usize)>,
}

pub fn resolve(findings: &[Finding], baseline: &Baseline) -> Resolution {
    let counts = count_by_group(findings);
    let mut res = Resolution::default();
    for (key, &actual) in &counts {
        let allowed = baseline.entries.get(key).copied().unwrap_or(0);
        if actual > allowed {
            res.violations.extend(
                findings
                    .iter()
                    .filter(|f| f.rule == key.0 && f.file == key.1)
                    .cloned(),
            );
        } else if actual < allowed {
            res.slack.push((key.0.clone(), key.1.clone(), allowed, actual));
        }
    }
    for (key, &allowed) in &baseline.entries {
        if !counts.contains_key(key) {
            res.slack.push((key.0.clone(), key.1.clone(), allowed, 0));
        }
    }
    res.violations.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    res.slack.sort();
    res
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(rule: &'static str, file: &str, line: usize) -> Finding {
        Finding { rule, file: file.to_string(), line, message: String::new() }
    }

    #[test]
    fn parse_render_round_trip() {
        let b = Baseline::parse("# comment\npanicking-decode util/codec.rs 2\n").unwrap();
        assert_eq!(
            b.entries.get(&("panicking-decode".into(), "util/codec.rs".into())),
            Some(&2)
        );
        let fs = vec![
            finding("panicking-decode", "util/codec.rs", 10),
            finding("panicking-decode", "util/codec.rs", 20),
        ];
        let rendered = Baseline::render(&fs);
        assert_eq!(Baseline::parse(&rendered).unwrap(), b);
    }

    #[test]
    fn parse_rejects_malformed_and_zero_entries() {
        assert!(Baseline::parse("only-two fields\n").is_err());
        assert!(Baseline::parse("rule file notanumber\n").is_err());
        assert!(Baseline::parse("rule file 0\n").is_err());
    }

    #[test]
    fn ratchet_fails_above_warns_below() {
        let base = Baseline::parse("panicking-decode util/codec.rs 2\n").unwrap();
        let two = vec![
            finding("panicking-decode", "util/codec.rs", 10),
            finding("panicking-decode", "util/codec.rs", 20),
        ];
        // at baseline: clean, no slack
        let r = resolve(&two, &base);
        assert!(r.violations.is_empty() && r.slack.is_empty());

        // one extra finding: the whole group fails
        let mut three = two.clone();
        three.push(finding("panicking-decode", "util/codec.rs", 30));
        let r = resolve(&three, &base);
        assert_eq!(r.violations.len(), 3);

        // debt paid down: passes, slack reported for tightening
        let one = &two[..1];
        let r = resolve(one, &base);
        assert!(r.violations.is_empty());
        assert_eq!(r.slack, vec![("panicking-decode".into(), "util/codec.rs".into(), 2, 1)]);

        // stale entry (file now clean) is slack too
        let r = resolve(&[], &base);
        assert_eq!(r.slack, vec![("panicking-decode".into(), "util/codec.rs".into(), 2, 0)]);
    }

    #[test]
    fn validate_rules_rejects_unknown_names() {
        let b = Baseline::parse("panicking-decode util/codec.rs 2\n").unwrap();
        assert!(b.validate_rules(&["panicking-decode", "unordered-iter"]).is_ok());
        let err = b.validate_rules(&["unordered-iter"]).unwrap_err().to_string();
        assert!(err.contains("unknown rule"), "{err}");
        assert!(err.contains("panicking-decode"), "{err}");
    }

    #[test]
    fn new_files_start_clean() {
        let base = Baseline::default();
        let r = resolve(&[finding("unordered-iter", "simulation/new.rs", 5)], &base);
        assert_eq!(r.violations.len(), 1);
    }
}
