//! Wire-schema extraction and encode/decode symmetry checking.
//!
//! The sim==deploy contract rides on the framed protocol: every
//! `encode_*` writer must be mirrored byte-for-byte by its `decode_*`
//! reader.  PR 6 enforced that only dynamically (fuzz round-trips);
//! this pass recovers each side's *opcode sequence* from the stripped
//! source and compares them statically.
//!
//! Model:
//!
//!   * Ops come from method calls on *tracked* codec values — params
//!     typed `&mut Encoder`/`&mut Decoder`, or locals bound from
//!     `Encoder::`/`Decoder::` constructors.  `put_u32`, `put_len`,
//!     `try_put_u32` and the reader's `u32()`/`count(_)` all collapse
//!     to the same 4-byte opcode, so LEN==U32 equivalences hold.
//!   * A call whose argument list mentions a tracked codec and whose
//!     name is `encode_*`/`decode_*`-shaped becomes a `sub:<suffix>`
//!     opcode — nested schemas compare by suffix, not by body.
//!   * `for`/`while` bodies become `loop[...]`; `if`/`match` become
//!     `alt{...}` branch sets.  Normalization drops empty branches
//!     (error arms), hoists shared leading ops, collapses
//!     single-branch alts, and rewrites a per-byte `loop[u8]` to
//!     `raw` — so an optional-field `match` and its tag-prefix read
//!     compare equal when they are wire-equivalent.  (The flattening
//!     means *optionality itself* is not checked, only the byte shape
//!     of each path.)
//!   * `Msg::encode`-shaped fns (a single `match` whose arms each
//!     open with `put_u8(<literal tag>)`) pair arm-by-arm against
//!     `Msg::decode`-shaped fns (a tag byte read, then a `match` over
//!     integer literals): per-tag mismatches and missing arms are
//!     reported individually.  A wildcard decode arm absorbs
//!     otherwise-unmatched encode tags.
//!
//! Pairing key is (impl type | file, name suffix); fns with *no*
//! tracked codec value are delegators (`to_bytes`, `encoded`) and are
//! skipped, as are pairs where either side is missing.  Two further
//! rules share this pass: `unguarded-len-alloc` (a `u32/u16/u64`
//! length read driving `with_capacity`/`vec![` without a bounds check
//! first — `count()` reads are pre-checked by the Decoder and exempt)
//! and `unfuzzed-variant` (`Msg` variants missing from
//! `rust/tests/fuzz_decode.rs::sample_msgs`).

use super::callgraph::SourceFile;
use super::lexer::{analyze_source, SourceMap};
use super::rules::{self, Finding};
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

#[derive(Debug, Clone, PartialEq, Eq)]
enum Op {
    Int(u8),
    Float(u8),
    Str,
    Bytes,
    F32s,
    U16s,
    Raw,
    Sub(String),
    Loop(Vec<Op>),
    Alt(Vec<Branch>),
}

#[derive(Debug, Clone, PartialEq, Eq)]
struct Branch {
    pattern: String,
    /// Literal argument of a leading `put_u8(<n>)`, when it is the
    /// branch's first op — the encode side's wire tag.
    first_lit: Option<u64>,
    ops: Vec<Op>,
}

struct Seq {
    ops: Vec<Op>,
    first_lit: Option<u64>,
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

/// Opcode for a codec method call, shared by both directions so
/// equivalent widths (`put_len`/`try_put_u32`/`u32`/`count`) unify.
fn method_op(name: &str) -> Option<Op> {
    Some(match name {
        "put_u8" | "u8" => Op::Int(1),
        "put_u16" | "u16" => Op::Int(2),
        "put_u32" | "put_len" | "try_put_u32" | "u32" | "count" => Op::Int(4),
        "put_u64" | "u64" => Op::Int(8),
        "put_f32" | "f32" => Op::Float(4),
        "put_f64" | "f64" => Op::Float(8),
        "put_str" | "str" => Op::Str,
        "put_bytes" | "bytes" => Op::Bytes,
        "put_f32s" | "f32s" => Op::F32s,
        "put_u16s" | "u16s" => Op::U16s,
        "put_raw" | "raw" => Op::Raw,
        _ => return None,
    })
}

/// `encode`-family name -> pairing suffix (`encode_meta` -> `meta`).
fn encode_suffix(name: &str) -> Option<String> {
    match name {
        "encode" | "encode_with" | "encoded" | "encoded_with" => Some(String::new()),
        _ => name.strip_prefix("encode_").map(str::to_string),
    }
}

fn decode_suffix(name: &str) -> Option<String> {
    match name {
        "decode" | "decode_with" | "from_bytes" => Some(String::new()),
        _ => name.strip_prefix("decode_").map(str::to_string),
    }
}

fn sub_label(name: &str) -> Option<String> {
    encode_suffix(name).or_else(|| decode_suffix(name))
}

/// Matching `}` for the `{` at `open`, bounded by `end`.
fn brace_match(b: &[u8], open: usize, end: usize) -> usize {
    let mut depth = 0usize;
    let mut i = open;
    while i < end {
        match b[i] {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return i;
                }
            }
            _ => {}
        }
        i += 1;
    }
    end.saturating_sub(1)
}

fn paren_match(b: &[u8], open: usize, end: usize) -> usize {
    let mut depth = 0usize;
    let mut i = open;
    while i < end {
        match b[i] {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return i;
                }
            }
            _ => {}
        }
        i += 1;
    }
    end.saturating_sub(1)
}

fn find_brace(b: &[u8], from: usize, end: usize) -> Option<usize> {
    (from..end).find(|&i| b[i] == b'{')
}

struct BodyParser<'a> {
    b: &'a [u8],
    text: &'a str,
    tracked: &'a BTreeSet<String>,
}

fn push_op(ops: &mut Vec<Op>, first_lit: &mut Option<u64>, op: Op, lit: Option<u64>) {
    if ops.is_empty() && matches!(op, Op::Int(1)) {
        *first_lit = lit;
    }
    ops.push(op);
}

impl<'a> BodyParser<'a> {
    fn word(&self, from: usize, end: usize) -> (usize, usize) {
        let mut j = from;
        while j < end && is_ident(self.b[j]) {
            j += 1;
        }
        (from, j)
    }

    fn skip_ws(&self, mut i: usize, end: usize) -> usize {
        while i < end && (self.b[i] as char).is_whitespace() {
            i += 1;
        }
        i
    }

    fn parse_range(&self, mut i: usize, end: usize) -> Seq {
        let b = self.b;
        let mut ops: Vec<Op> = Vec::new();
        let mut first_lit: Option<u64> = None;
        while i < end {
            if !is_ident_start(b[i]) || (i > 0 && is_ident(b[i - 1])) {
                i += 1;
                continue;
            }
            let (ws, j) = self.word(i, end);
            let w = &self.text[ws..j];
            match w {
                "for" | "while" | "loop" => {
                    let Some(open) = find_brace(b, j, end) else {
                        i = j;
                        continue;
                    };
                    let close = brace_match(b, open, end);
                    let header = self.parse_range(j, open);
                    for op in header.ops {
                        push_op(&mut ops, &mut first_lit, op, None);
                    }
                    let body = self.parse_range(open + 1, close);
                    if !body.ops.is_empty() {
                        push_op(&mut ops, &mut first_lit, Op::Loop(body.ops), None);
                    }
                    i = close + 1;
                }
                "if" => {
                    let (branches, after) = self.parse_if_chain(j, end, &mut ops, &mut first_lit);
                    if branches.iter().any(|br| !br.ops.is_empty()) {
                        push_op(&mut ops, &mut first_lit, Op::Alt(branches), None);
                    }
                    i = after;
                }
                "match" => {
                    let Some(open) = find_brace(b, j, end) else {
                        i = j;
                        continue;
                    };
                    let scrut = self.parse_range(j, open);
                    for op in scrut.ops {
                        push_op(&mut ops, &mut first_lit, op, None);
                    }
                    let close = brace_match(b, open, end);
                    let arms = self.parse_arms(open + 1, close);
                    if arms.iter().any(|a| !a.ops.is_empty()) {
                        push_op(&mut ops, &mut first_lit, Op::Alt(arms), None);
                    }
                    i = close + 1;
                }
                _ => {
                    i = self.parse_call_like(ws, j, end, &mut ops, &mut first_lit);
                }
            }
        }
        Seq { ops, first_lit }
    }

    /// `if cond { .. } else if cond { .. } else { .. }` -> branches.
    /// The first condition's ops run unconditionally (emitted into the
    /// caller's seq); later conditions are folded into their branch.
    fn parse_if_chain(
        &self,
        mut i: usize,
        end: usize,
        ops: &mut Vec<Op>,
        first_lit: &mut Option<u64>,
    ) -> (Vec<Branch>, usize) {
        let b = self.b;
        let mut branches: Vec<Branch> = Vec::new();
        let mut has_else = false;
        loop {
            let Some(open) = find_brace(b, i, end) else { break };
            let close = brace_match(b, open, end);
            let cond = self.parse_range(i, open);
            let body = self.parse_range(open + 1, close);
            if branches.is_empty() {
                for op in cond.ops {
                    push_op(ops, first_lit, op, None);
                }
                branches.push(Branch {
                    pattern: String::new(),
                    first_lit: body.first_lit,
                    ops: body.ops,
                });
            } else {
                let mut bo = cond.ops;
                bo.extend(body.ops);
                branches.push(Branch { pattern: String::new(), first_lit: None, ops: bo });
            }
            i = close + 1;
            let k = self.skip_ws(i, end);
            let (es, ee) = self.word(k, end);
            if ee > es && &self.text[es..ee] == "else" {
                let k2 = self.skip_ws(ee, end);
                let (fs, fe) = self.word(k2, end);
                if fe > fs && &self.text[fs..fe] == "if" {
                    i = fe;
                    continue;
                }
                // final `else { .. }`
                if let Some(open2) = find_brace(b, k2, end) {
                    let close2 = brace_match(b, open2, end);
                    let body2 = self.parse_range(open2 + 1, close2);
                    branches.push(Branch {
                        pattern: String::new(),
                        first_lit: body2.first_lit,
                        ops: body2.ops,
                    });
                    has_else = true;
                    i = close2 + 1;
                }
            }
            break;
        }
        if !has_else {
            branches.push(Branch { pattern: String::new(), first_lit: None, ops: Vec::new() });
        }
        (branches, i)
    }

    fn parse_arms(&self, start: usize, end: usize) -> Vec<Branch> {
        let b = self.b;
        let mut out = Vec::new();
        let mut i = start;
        loop {
            while i < end && ((b[i] as char).is_whitespace() || b[i] == b',') {
                i += 1;
            }
            if i >= end {
                break;
            }
            let ps = i;
            let mut depth = 0i32;
            while i < end {
                match b[i] {
                    b'(' | b'[' | b'{' => depth += 1,
                    b')' | b']' | b'}' => depth -= 1,
                    b'=' if depth == 0 && i + 1 < end && b[i + 1] == b'>' => break,
                    _ => {}
                }
                i += 1;
            }
            if i >= end {
                break;
            }
            let pattern = self.text[ps..i].trim().to_string();
            i += 2;
            while i < end && b[i] == b' ' {
                i += 1;
            }
            let seq;
            if i < end && b[i] == b'{' {
                let close = brace_match(b, i, end);
                seq = self.parse_range(i + 1, close);
                i = close + 1;
            } else {
                let es = i;
                let mut d = 0i32;
                while i < end {
                    match b[i] {
                        b'(' | b'[' | b'{' => d += 1,
                        b')' | b']' | b'}' => {
                            if d == 0 {
                                break;
                            }
                            d -= 1;
                        }
                        b',' if d == 0 => break,
                        _ => {}
                    }
                    i += 1;
                }
                seq = self.parse_range(es, i);
            }
            out.push(Branch { pattern, first_lit: seq.first_lit, ops: seq.ops });
        }
        out
    }

    /// Handle a non-keyword ident at `ws..j`: tracked-receiver method
    /// call, sub-schema call, or plain ident.  Returns the next scan
    /// position.
    fn parse_call_like(
        &self,
        ws: usize,
        j: usize,
        end: usize,
        ops: &mut Vec<Op>,
        first_lit: &mut Option<u64>,
    ) -> usize {
        let b = self.b;
        let w = &self.text[ws..j];
        if self.tracked.contains(w) && j < end && b[j] == b'.' {
            let (ms, me) = self.word(j + 1, end);
            if me > ms {
                let m = &self.text[ms..me];
                let k = self.skip_ws(me, end);
                if k < end && b[k] == b'(' {
                    if let Some(op) = method_op(m) {
                        let lit = if m == "put_u8" {
                            let pc = paren_match(b, k, end);
                            self.text[k + 1..pc].trim().parse::<u64>().ok()
                        } else {
                            None
                        };
                        push_op(ops, first_lit, op, lit);
                    }
                    // args are scanned by the main loop either way
                    // (e.g. `put_u8(match op { .. })`)
                    return k + 1;
                }
            }
            return j;
        }
        // path call: follow `::` segments to the final name
        let mut last = w.to_string();
        let mut after = j;
        loop {
            if after + 1 < end && b[after] == b':' && b[after + 1] == b':' {
                let s2 = after + 2;
                if s2 < end && is_ident_start(b[s2]) {
                    let (_, e2) = self.word(s2, end);
                    last = self.text[s2..e2].to_string();
                    after = e2;
                    continue;
                }
            }
            break;
        }
        let k = self.skip_ws(after, end);
        if k < end && b[k] == b'!' {
            return after; // macro — args scanned naturally
        }
        if k < end && b[k] == b'(' {
            let pc = paren_match(b, k, end);
            let args = &self.text[k + 1..pc];
            if let Some(label) = sub_label(&last) {
                if self.tracked.iter().any(|t| rules::word_in(args, t)) {
                    push_op(ops, first_lit, Op::Sub(label), None);
                    return pc + 1; // nested schema: don't double-count its args
                }
            }
            return k + 1;
        }
        after
    }
}

fn render(ops: &[Op]) -> String {
    ops.iter().map(render_op).collect::<Vec<_>>().join(" ")
}

fn render_op(op: &Op) -> String {
    match op {
        Op::Int(n) => format!("u{}", 8 * *n as usize),
        Op::Float(n) => format!("f{}", 8 * *n as usize),
        Op::Str => "str".into(),
        Op::Bytes => "bytes".into(),
        Op::F32s => "f32s".into(),
        Op::U16s => "u16s".into(),
        Op::Raw => "raw".into(),
        Op::Sub(l) => {
            if l.is_empty() {
                "sub".into()
            } else {
                format!("sub:{l}")
            }
        }
        Op::Loop(body) => format!("loop[{}]", render(body)),
        Op::Alt(bs) => {
            let parts: Vec<String> = bs.iter().map(|br| render(&br.ops)).collect();
            format!("alt{{{}}}", parts.join(" | "))
        }
    }
}

/// Canonicalize: normalize branches, drop empty ones (error arms),
/// hoist shared leading ops, collapse single branches, `loop[u8]` ->
/// `raw`.
fn normalize(ops: Vec<Op>) -> Vec<Op> {
    let mut out = Vec::new();
    for op in ops {
        match op {
            Op::Loop(body) => {
                let body = normalize(body);
                if body.is_empty() {
                    // loop with no wire effect
                } else if body == vec![Op::Int(1)] {
                    out.push(Op::Raw);
                } else {
                    out.push(Op::Loop(body));
                }
            }
            Op::Alt(branches) => {
                let mut bs: Vec<Vec<Op>> =
                    branches.into_iter().map(|br| normalize(br.ops)).collect();
                bs.retain(|x| !x.is_empty());
                while bs.len() >= 2 && bs.iter().all(|x| x.first() == bs[0].first()) {
                    out.push(bs[0][0].clone());
                    for x in bs.iter_mut() {
                        x.remove(0);
                    }
                    bs.retain(|x| !x.is_empty());
                }
                if bs.is_empty() {
                    continue;
                }
                if bs.len() == 1 {
                    out.extend(bs.remove(0));
                    continue;
                }
                bs.sort_by_key(|x| render(x));
                out.push(Op::Alt(
                    bs.into_iter()
                        .map(|x| Branch { pattern: String::new(), first_lit: None, ops: x })
                        .collect(),
                ));
            }
            other => out.push(other),
        }
    }
    out
}

/// One encode- or decode-named fn with at least one tracked codec
/// value, ready for pairing.
struct WireFn {
    file: String,
    name: String,
    start: usize,
    ops: Vec<Op>,
}

/// Tracked codec idents: params typed with Encoder/Decoder + locals
/// bound from their constructors (+ `self` when requested).
fn tracked_idents(sig: &str, body_lines: &[String], with_self: bool) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    if with_self {
        out.insert("self".to_string());
    }
    if let Some(open) = sig.find('(') {
        let pb = sig.as_bytes();
        let close = paren_match(pb, open, sig.len());
        let params = &sig[open + 1..close];
        let mut depth = 0i32;
        let mut piece_start = 0usize;
        let bytes = params.as_bytes();
        let mut pieces: Vec<&str> = Vec::new();
        for (idx, &c) in bytes.iter().enumerate() {
            match c {
                b'(' | b'[' | b'<' => depth += 1,
                b')' | b']' | b'>' => depth -= 1,
                b',' if depth == 0 => {
                    pieces.push(&params[piece_start..idx]);
                    piece_start = idx + 1;
                }
                _ => {}
            }
        }
        pieces.push(&params[piece_start..]);
        for piece in pieces {
            if !(rules::word_in(piece, "Encoder") || rules::word_in(piece, "Decoder")) {
                continue;
            }
            let Some(name_part) = piece.split(':').next() else { continue };
            let name = name_part.trim().trim_start_matches("mut ").trim();
            if !name.is_empty() && name.bytes().all(is_ident) {
                out.insert(name.to_string());
            }
        }
    }
    for line in body_lines {
        let t = line.trim_start();
        if !t.starts_with("let ") {
            continue;
        }
        if !(line.contains("= Encoder::") || line.contains("= Decoder::")) {
            continue;
        }
        let rest = t["let ".len()..].trim_start();
        let rest = rest.strip_prefix("mut ").unwrap_or(rest);
        let name: String =
            rest.bytes().take_while(|&c| is_ident(c)).map(|c| c as char).collect();
        if !name.is_empty() {
            out.insert(name);
        }
    }
    out
}

/// `-> <'` in arrow types never has a `>` problem here: the depth
/// tracker above only guards comma splitting inside generics.
fn owner_of(map: &SourceMap, fn_start: usize) -> Option<String> {
    map.impls
        .iter()
        .filter(|im| im.start <= fn_start && fn_start <= im.end)
        .min_by_key(|im| im.end - im.start)
        .map(|im| im.type_name.clone())
}

fn extract_wire_fn(map: &SourceMap, rel: &str, f: &super::lexer::FnSpan) -> Option<WireFn> {
    let last = f.end.min(map.lines.len());
    if f.start > last {
        return None;
    }
    let span = map.lines[f.start - 1..last].join("\n");
    let b = span.as_bytes();
    let open = find_brace(b, 0, b.len())?;
    let sig = &span[..open];
    let body_lines: Vec<String> =
        map.lines[f.start - 1..last].iter().map(|l| l.to_string()).collect();
    let tracked = tracked_idents(sig, &body_lines, false);
    if tracked.is_empty() {
        return None; // delegator (`to_bytes`, `encoded`): no schema here
    }
    let close = brace_match(b, open, b.len());
    let parser = BodyParser { b, text: &span, tracked: &tracked };
    let seq = parser.parse_range(open + 1, close);
    Some(WireFn { file: rel.to_string(), name: f.name.clone(), start: f.start, ops: seq.ops })
}

/// `Msg::Ping { .. }` -> `Ping`.
fn variant_label(pattern: &str) -> String {
    let head = pattern.split(['{', '(']).next().unwrap_or("").trim();
    head.rsplit("::").next().unwrap_or(head).trim().to_string()
}

type EncArms = BTreeMap<u64, (String, Vec<Op>)>;

/// Tag-match shape, encode side: exactly `[ match { put_u8(N) .. } ]`.
fn enc_tag_shape(ops: &[Op]) -> Option<EncArms> {
    let [Op::Alt(branches)] = ops else { return None };
    let mut m = EncArms::new();
    let mut lits = 0usize;
    for br in branches {
        match br.first_lit {
            Some(tag) => {
                lits += 1;
                let body: Vec<Op> = br.ops.iter().skip(1).cloned().collect();
                if m.insert(tag, (variant_label(&br.pattern), body)).is_some() {
                    return None; // duplicate tag: let the generic compare report it
                }
            }
            None => {
                if !normalize(br.ops.clone()).is_empty() {
                    return None;
                }
            }
        }
    }
    if lits >= 2 {
        Some(m)
    } else {
        None
    }
}

/// Tag-match shape, decode side: `[ u8, match <tag> { N => .. } ]`.
/// Returns (arms, has_wildcard_arm).
fn dec_tag_shape(ops: &[Op]) -> Option<(BTreeMap<u64, Vec<Op>>, bool)> {
    let [Op::Int(1), Op::Alt(branches)] = ops else { return None };
    let mut m = BTreeMap::new();
    let mut wildcard = false;
    let mut lits = 0usize;
    for br in branches {
        match br.pattern.trim().parse::<u64>() {
            Ok(tag) => {
                lits += 1;
                m.insert(tag, br.ops.clone());
            }
            Err(_) => {
                if !normalize(br.ops.clone()).is_empty() {
                    return None; // op-bearing wildcard arm: generic compare
                }
                wildcard = true;
            }
        }
    }
    // Tag-shaped when the match distinguishes at least two wire tags —
    // a wildcard that absorbs the remaining tags counts as one.
    if lits >= 2 || (lits == 1 && wildcard) {
        Some((m, wildcard))
    } else {
        None
    }
}

fn compare_pair(enc: &WireFn, dec: &WireFn) -> Vec<Finding> {
    let mut out = Vec::new();
    if let (Some(enc_arms), Some((dec_arms, wildcard))) =
        (enc_tag_shape(&enc.ops), dec_tag_shape(&dec.ops))
    {
        for (tag, (label, eops)) in &enc_arms {
            match dec_arms.get(tag) {
                Some(dops) => {
                    let a = render(&normalize(eops.clone()));
                    let d = render(&normalize(dops.clone()));
                    if a != d {
                        out.push(Finding {
                            rule: "wire-asymmetry",
                            file: dec.file.clone(),
                            line: dec.start,
                            message: format!(
                                "tag {tag} ({label}): `{}` writes [{a}] after the tag byte \
                                 but `{}` reads [{d}] — field order and widths must mirror \
                                 exactly (u32 covers put_len/try_put_u32/count)",
                                enc.name, dec.name
                            ),
                        });
                    }
                }
                None if !wildcard => out.push(Finding {
                    rule: "wire-asymmetry",
                    file: dec.file.clone(),
                    line: dec.start,
                    message: format!(
                        "tag {tag} ({label}) is written by `{}` but `{}` has no arm for it",
                        enc.name, dec.name
                    ),
                }),
                None => {}
            }
        }
        for tag in dec_arms.keys() {
            if !enc_arms.contains_key(tag) {
                out.push(Finding {
                    rule: "wire-asymmetry",
                    file: dec.file.clone(),
                    line: dec.start,
                    message: format!(
                        "`{}` reads tag {tag} but `{}` never writes it",
                        dec.name, enc.name
                    ),
                });
            }
        }
        return out;
    }
    let a = render(&normalize(enc.ops.clone()));
    let d = render(&normalize(dec.ops.clone()));
    if a != d {
        out.push(Finding {
            rule: "wire-asymmetry",
            file: dec.file.clone(),
            line: dec.start,
            message: format!(
                "`{}` writes [{a}] but `{}` reads [{d}] — field order and widths must \
                 mirror exactly (u32 covers put_len/try_put_u32/count; error/None arms \
                 are ignored)",
                enc.name, dec.name
            ),
        });
    }
    out
}

fn pair_findings(files: &[SourceFile], out: &mut Vec<Finding>) {
    let mut encs: BTreeMap<(String, String), Vec<WireFn>> = BTreeMap::new();
    let mut decs: BTreeMap<(String, String), Vec<WireFn>> = BTreeMap::new();
    for sf in files {
        for f in &sf.map.fns {
            if sf.map.line_is_test(f.start) {
                continue;
            }
            let (is_enc, suffix) = if let Some(s) = encode_suffix(&f.name) {
                (true, s)
            } else if let Some(s) = decode_suffix(&f.name) {
                (false, s)
            } else {
                continue;
            };
            let Some(wf) = extract_wire_fn(&sf.map, &sf.rel, f) else { continue };
            let scope = owner_of(&sf.map, f.start).unwrap_or_else(|| sf.rel.clone());
            let key = (scope, suffix);
            if is_enc {
                encs.entry(key).or_default().push(wf);
            } else {
                decs.entry(key).or_default().push(wf);
            }
        }
    }
    for (key, dlist) in &decs {
        let Some(elist) = encs.get(key) else { continue };
        for d in dlist {
            let mut best: Option<Vec<Finding>> = None;
            for e in elist {
                let fs = compare_pair(e, d);
                if fs.is_empty() {
                    best = Some(Vec::new());
                    break;
                }
                if best.as_ref().map_or(true, |b| fs.len() < b.len()) {
                    best = Some(fs);
                }
            }
            out.extend(best.unwrap_or_default());
        }
    }
}

fn is_guard_line(line: &str) -> bool {
    ["ensure!", "bail!(", "charge_dense(", ".min(", "<=", ">=", " < ", " > ", "assert!"]
        .iter()
        .any(|p| line.contains(p))
}

fn alloc_findings(files: &[SourceFile], out: &mut Vec<Finding>) {
    const READS: [&str; 3] = [".u32()", ".u16()", ".u64()"];
    for sf in files {
        let scope = rules::decode_scope(&sf.map);
        for f in &sf.map.fns {
            if sf.map.line_is_test(f.start) || !scope.get(f.start - 1).copied().unwrap_or(false)
            {
                continue;
            }
            let last = f.end.min(sf.map.lines.len());
            let span = sf.map.lines[f.start - 1..last].join("\n");
            let open = find_brace(span.as_bytes(), 0, span.len()).unwrap_or(0);
            let body_lines: Vec<String> =
                sf.map.lines[f.start - 1..last].iter().map(|l| l.to_string()).collect();
            let tracked = tracked_idents(&span[..open], &body_lines, true);
            if tracked.is_empty() {
                continue;
            }
            let mut unchecked: Vec<String> = Vec::new();
            for ln in f.start..=last {
                if sf.map.line_is_test(ln) {
                    continue;
                }
                let line = &sf.map.lines[ln - 1];
                let guarded = is_guard_line(line);
                if guarded {
                    unchecked.retain(|id| !rules::word_in(line, id));
                }
                let allocs = line.contains("with_capacity(") || line.contains("vec![");
                if allocs {
                    let hit = unchecked.iter().position(|id| rules::word_in(line, id));
                    if let Some(pos) = hit {
                        let id = unchecked.remove(pos);
                        out.push(Finding {
                            rule: "unguarded-len-alloc",
                            file: sf.rel.clone(),
                            line: ln,
                            message: format!(
                                "allocation sized by unchecked wire length `{id}` in \
                                 `{}` — a hostile frame can claim a huge count; bound \
                                 it (ensure!/charge_dense/Decoder::count) before \
                                 allocating",
                                f.name
                            ),
                        });
                    } else if !guarded
                        && tracked
                            .iter()
                            .any(|t| READS.iter().any(|r| line.contains(&format!("{t}{r}"))))
                    {
                        out.push(Finding {
                            rule: "unguarded-len-alloc",
                            file: sf.rel.clone(),
                            line: ln,
                            message: format!(
                                "allocation sized directly by an unchecked wire read in \
                                 `{}` — bound the length before allocating",
                                f.name
                            ),
                        });
                    }
                }
                let t = line.trim_start();
                if t.starts_with("let ") && line.contains(" as usize") {
                    let reads_len =
                        tracked.iter().any(|tr| READS.iter().any(|r| line.contains(&format!("{tr}{r}"))));
                    if reads_len && !guarded {
                        let rest = t["let ".len()..].trim_start();
                        let rest = rest.strip_prefix("mut ").unwrap_or(rest);
                        let name: String =
                            rest.bytes().take_while(|&c| is_ident(c)).map(|c| c as char).collect();
                        if !name.is_empty() {
                            unchecked.push(name);
                        }
                    }
                }
            }
        }
    }
}

/// `Msg` variants and their declaration lines from the first non-test
/// `enum Msg` in the tree.
fn msg_variants(files: &[SourceFile]) -> Option<(String, Vec<(String, usize)>)> {
    for sf in files {
        let flat = sf.map.lines.join("\n");
        let b = flat.as_bytes();
        let mut pos = 0usize;
        while let Some(off) = flat[pos..].find("enum") {
            let at = pos + off;
            pos = at + 4;
            let pre_ok = at == 0 || !is_ident(b[at - 1]);
            let post_ok = at + 4 < b.len() && !is_ident(b[at + 4]);
            if !pre_ok || !post_ok {
                continue;
            }
            let mut k = at + 4;
            while k < b.len() && (b[k] as char).is_whitespace() {
                k += 1;
            }
            let mut e = k;
            while e < b.len() && is_ident(b[e]) {
                e += 1;
            }
            if &flat[k..e] != "Msg" {
                continue;
            }
            let line_no = flat[..at].bytes().filter(|&c| c == b'\n').count() + 1;
            if sf.map.line_is_test(line_no) {
                continue;
            }
            let Some(open) = find_brace(b, e, b.len()) else { continue };
            let close = brace_match(b, open, b.len());
            let mut variants = Vec::new();
            let mut depth = 0i32;
            let mut seg_start = open + 1;
            let mut i = open + 1;
            while i <= close {
                let c = b[i];
                let at_end = i == close;
                if !at_end {
                    match c {
                        b'(' | b'[' | b'{' => depth += 1,
                        b')' | b']' | b'}' => depth -= 1,
                        _ => {}
                    }
                }
                if (c == b',' && depth == 0) || at_end {
                    let seg = &flat[seg_start..i];
                    let mut off2 = 0usize;
                    let sb = seg.as_bytes();
                    while off2 < sb.len() {
                        if sb[off2] == b'#' {
                            // attribute: skip through `]`
                            while off2 < sb.len() && sb[off2] != b']' {
                                off2 += 1;
                            }
                            off2 += 1;
                        } else if (sb[off2] as char).is_whitespace() {
                            off2 += 1;
                        } else {
                            break;
                        }
                    }
                    let ns = off2;
                    while off2 < sb.len() && is_ident(sb[off2]) {
                        off2 += 1;
                    }
                    let name = &seg[ns..off2];
                    if name.chars().next().is_some_and(|ch| ch.is_ascii_uppercase()) {
                        let vline = flat[..seg_start + ns].bytes().filter(|&ch| ch == b'\n').count() + 1;
                        variants.push((name.to_string(), vline));
                    }
                    seg_start = i + 1;
                }
                i += 1;
            }
            if !variants.is_empty() {
                return Some((sf.rel.clone(), variants));
            }
        }
    }
    None
}

fn fuzz_findings(files: &[SourceFile], repo_root: &Path, out: &mut Vec<Finding>) {
    let Some((msg_file, variants)) = msg_variants(files) else { return };
    let fuzz_path = repo_root.join("rust").join("tests").join("fuzz_decode.rs");
    let Ok(src) = std::fs::read_to_string(&fuzz_path) else { return };
    let fmap = analyze_source(&src);
    let Some(f) = fmap.fns.iter().find(|f| f.name == "sample_msgs") else { return };
    let last = f.end.min(fmap.lines.len());
    let span = fmap.lines[f.start - 1..last].join("\n");
    for (v, line) in variants {
        let pat = format!("Msg::{v}");
        let covered = span.match_indices(&pat).any(|(i, _)| {
            let after = i + pat.len();
            after >= span.len() || !is_ident(span.as_bytes()[after])
        });
        if !covered {
            out.push(Finding {
                rule: "unfuzzed-variant",
                file: msg_file.clone(),
                line,
                message: format!(
                    "`Msg::{v}` is never constructed in \
                     rust/tests/fuzz_decode.rs::sample_msgs — every variant must \
                     round-trip under fuzz; add it to the sample pool"
                ),
            });
        }
    }
}

/// All three wire rules over the loaded tree.
pub fn check(files: &[SourceFile], repo_root: &Path) -> Vec<Finding> {
    let mut out = Vec::new();
    pair_findings(files, &mut out);
    alloc_findings(files, &mut out);
    fuzz_findings(files, repo_root, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sf(rel: &str, src: &str) -> SourceFile {
        SourceFile { rel: rel.to_string(), map: analyze_source(src) }
    }

    fn pairs_only(files: &[SourceFile]) -> Vec<Finding> {
        let mut out = Vec::new();
        pair_findings(files, &mut out);
        out
    }

    #[test]
    fn symmetric_pair_passes_asymmetric_fails() {
        let good = sf(
            "compress/mod.rs",
            "pub fn encode_point(enc: &mut Encoder, x: u32, y: f32) {\n    enc.put_u32(x);\n    enc.put_f32(y);\n}\npub fn decode_point(dec: &mut Decoder) -> (u32, f32) {\n    let x = dec.u32();\n    let y = dec.f32();\n    (x, y)\n}\n",
        );
        assert!(pairs_only(&[good]).is_empty());
        let bad = sf(
            "compress/mod.rs",
            "pub fn encode_point(enc: &mut Encoder, x: u32, y: f32) {\n    enc.put_u32(x);\n    enc.put_f32(y);\n}\npub fn decode_point(dec: &mut Decoder) -> (u32, f32) {\n    let y = dec.f32();\n    let x = dec.u32();\n    (x, y)\n}\n",
        );
        let f = pairs_only(&[bad]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "wire-asymmetry");
        assert!(f[0].message.contains("[u32 f32]"), "{}", f[0].message);
        assert!(f[0].message.contains("[f32 u32]"));
    }

    #[test]
    fn loops_subs_and_len_equivalences_unify() {
        let files = sf(
            "model/params.rs",
            "pub fn encode_rows(enc: &mut Encoder, rows: &[Vec[f32]]) {\n    enc.put_len(rows.len())?;\n    for r in rows {\n        enc.try_put_u32(r.id)?;\n        crate::compress::encode_f32s(enc, r, codec)?;\n    }\n}\npub fn decode_rows(dec: &mut Decoder) -> Vec<Row> {\n    let n = dec.count(8)?;\n    for _ in 0..n {\n        let id = dec.u32()?;\n        let xs = crate::compress::decode_f32s(dec)?;\n    }\n}\n",
        );
        assert!(pairs_only(&[files]).is_empty());
    }

    #[test]
    fn option_tag_match_flattens_symmetrically() {
        let files = sf(
            "coordinator/messages.rs",
            "fn encode_extra(enc: &mut Encoder, extra: &Option<Vec<u8>>) {\n    match extra {\n        None => enc.put_u8(0),\n        Some(p) => {\n            enc.put_u8(1);\n            enc.put_bytes(p);\n        }\n    }\n}\nfn decode_extra(dec: &mut Decoder) -> Option<Vec<u8>> {\n    match dec.u8()? {\n        0 => None,\n        1 => Some(dec.bytes()?.to_vec()),\n        _ => bail!(\"tag\"),\n    }\n}\n",
        );
        assert!(pairs_only(&[files]).is_empty(), "{:?}", pairs_only(&[files]));
    }

    #[test]
    fn per_byte_loop_equals_raw() {
        let files = sf(
            "compress/mod.rs",
            "pub fn encode_blob(enc: &mut Encoder, xs: &[u8]) {\n    enc.put_len(xs.len())?;\n    for x in xs {\n        enc.put_u8(*x);\n    }\n}\npub fn decode_blob(dec: &mut Decoder) -> Vec<u8> {\n    let n = dec.count(1)?;\n    dec.raw(n)?.to_vec()\n}\n",
        );
        assert!(pairs_only(&[files]).is_empty(), "{:?}", pairs_only(&[files]));
    }

    #[test]
    fn msg_arm_pairing_reports_tag_level_mismatches() {
        let files = sf(
            "coordinator/messages.rs",
            "impl Msg {\n    pub fn encode(&self) -> Vec<u8> {\n        let mut enc = Encoder::new();\n        match self {\n            Msg::Ping { seq } => {\n                enc.put_u8(0);\n                enc.put_u32(*seq);\n            }\n            Msg::Stop => enc.put_u8(1),\n        }\n        enc.finish()\n    }\n    pub fn decode(buf: &[u8]) -> Msg {\n        let mut dec = Decoder::new(buf);\n        let tag = dec.u8();\n        match tag {\n            0 => Msg::Ping { seq: dec.u64() },\n            _ => Msg::Stop,\n        }\n    }\n}\n",
        );
        let f = pairs_only(&[files]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("tag 0 (Ping)"), "{}", f[0].message);
        assert!(f[0].message.contains("[u32]"));
        assert!(f[0].message.contains("[u64]"));
    }

    #[test]
    fn delegators_without_codec_idents_are_skipped() {
        let files = sf(
            "model/params.rs",
            "impl ParamSet {\n    pub fn encode(&self) -> Vec<u8> {\n        let mut enc = Encoder::new();\n        self.encode_with(&mut enc);\n        enc.finish()\n    }\n    pub fn encode_with(&self, enc: &mut Encoder) {\n        enc.put_u32(self.n);\n    }\n    pub fn from_bytes(bytes: &[u8]) -> Self {\n        Self::decode_all(bytes)\n    }\n    pub fn decode(dec: &mut Decoder) -> Self {\n        ParamSet { n: dec.u32() }\n    }\n}\n",
        );
        // `encode` (delegator seq [sub]) never matches `decode` ([u32]),
        // but `encode_with` does — any-candidate-match passes the pair.
        assert!(pairs_only(&[files]).is_empty(), "{:?}", pairs_only(&[files]));
    }

    #[test]
    fn unguarded_len_alloc_fires_and_guards_suppress() {
        let bad = sf(
            "compress/mod.rs",
            "pub fn decode_table(dec: &mut Decoder) -> Vec<u64> {\n    let n = dec.u32() as usize;\n    let mut out = Vec::with_capacity(n);\n    out\n}\n",
        );
        let mut f = Vec::new();
        alloc_findings(&[bad], &mut f);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "unguarded-len-alloc");
        assert_eq!(f[0].line, 3);

        let good = sf(
            "compress/mod.rs",
            "pub fn decode_table(dec: &mut Decoder) -> Vec<u64> {\n    let n = dec.u32() as usize;\n    ensure!(n <= 1024, \"oversized\");\n    let mut out = Vec::with_capacity(n);\n    out\n}\n",
        );
        let mut g = Vec::new();
        alloc_findings(&[good], &mut g);
        assert!(g.is_empty(), "{g:?}");

        let counted = sf(
            "compress/mod.rs",
            "pub fn decode_table(dec: &mut Decoder) -> Vec<u64> {\n    let n = dec.count(8)?;\n    let mut out = Vec::with_capacity(n);\n    out\n}\n",
        );
        let mut c = Vec::new();
        alloc_findings(&[counted], &mut c);
        assert!(c.is_empty(), "count() is bounds-checked by the Decoder: {c:?}");
    }

    #[test]
    fn normalization_drops_error_arms_and_hoists() {
        let seq = vec![Op::Alt(vec![
            Branch { pattern: "0".into(), first_lit: None, ops: vec![Op::Int(1)] },
            Branch {
                pattern: "1".into(),
                first_lit: None,
                ops: vec![Op::Int(1), Op::Bytes],
            },
            Branch { pattern: "t".into(), first_lit: None, ops: vec![] },
        ])];
        assert_eq!(render(&normalize(seq)), "u8 bytes");
    }
}
