//! `parrot lint` — a repo-local determinism & wire-safety
//! static-analysis pass.
//!
//! The ROADMAP's parallel-simulation step ("same seed ≡ same trace
//! across thread counts") is only attemptable with zero hidden
//! nondeterminism, and the sim==deploy differentials of PRs 3–5 place
//! the same obligation on the wire path.  This subsystem turns that
//! discipline from reviewer folklore into a CI gate:
//!
//!   * [`lexer`] — comment/string-stripping scanner over
//!     `rust/src/**/*.rs` recovering `#[cfg(test)]` regions and
//!     fn/impl spans (no external parser; the build is offline),
//!   * [`rules`] — the per-file rules, their module-scoped policy,
//!     and the registry behind `--explain`,
//!   * [`callgraph`] — crate-wide call-site extraction with a
//!     conservative unknown-and-reported resolution policy,
//!   * [`effects`] — per-fn effect bits propagated to a fixpoint
//!     over the call graph (the `*-transitive` rules),
//!   * [`wire`] — encode/decode opcode-sequence recovery and
//!     symmetry checking,
//!   * [`baseline`] — the committed grandfather file and its
//!     one-way ratchet.
//!
//! `parrot lint` emits human or JSON-lines output and exits nonzero
//! on any finding not covered by `lint.baseline`; `scripts/ci.sh`
//! runs it after the release build.

pub mod baseline;
pub mod callgraph;
pub mod effects;
pub mod lexer;
pub mod rules;
pub mod wire;

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use baseline::{Baseline, Resolution};
use callgraph::{CallGraph, SourceFile, Unresolved};
use rules::Finding;
use std::path::{Path, PathBuf};

/// Recursively collect `.rs` files under `dir`, sorted by relative
/// path so findings (and the rendered baseline) are order-stable.
fn collect_rs_files(dir: &Path) -> Result<Vec<PathBuf>> {
    fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
        let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
            .with_context(|| format!("read dir {}", dir.display()))?
            .map(|e| e.map(|e| e.path()))
            .collect::<std::io::Result<_>>()?;
        entries.sort();
        for p in entries {
            if p.is_dir() {
                walk(&p, out)?;
            } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
                out.push(p);
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    walk(dir, &mut out)?;
    Ok(out)
}

/// One whole-program analysis pass: findings plus the call-graph
/// accounting behind them.
pub struct Analysis {
    pub findings: Vec<Finding>,
    /// Call sites the resolver could not link — conservatively
    /// surfaced, never silently dropped.
    pub unresolved: Vec<Unresolved>,
    pub n_fns: usize,
    pub n_edges: usize,
}

/// Run every rule — per-file, transitive, and wire — over the tree
/// rooted at `repo_root` (which must contain `rust/src`).  Findings
/// are sorted by (file, line, rule).
pub fn run(repo_root: &Path) -> Result<Analysis> {
    let src_root = repo_root.join("rust").join("src");
    if !src_root.is_dir() {
        bail!("{} has no rust/src — pass the repo root via --root", repo_root.display());
    }
    let mut files = Vec::new();
    for path in collect_rs_files(&src_root)? {
        let rel = path
            .strip_prefix(&src_root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src =
            std::fs::read_to_string(&path).with_context(|| format!("read {}", path.display()))?;
        files.push(SourceFile { rel, map: lexer::analyze_source(&src) });
    }
    let mut findings = Vec::new();
    for sf in &files {
        findings.extend(rules::check_map(&sf.rel, &sf.map));
    }
    let cg = CallGraph::build(&files);
    let fx = effects::compute(&cg, &files);
    findings.extend(effects::transitive_findings(&cg, &fx, &files));
    findings.extend(wire::check(&files, repo_root));
    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    let (n_fns, n_edges) = (cg.fns.len(), cg.calls.len());
    Ok(Analysis { findings, unresolved: cg.unresolved, n_fns, n_edges })
}

/// One JSON-lines record per finding — the `--format json` output
/// consumed by CI tooling.  Built through `util::json` so messages
/// that quote source (the call-chain messages do) stay valid JSON.
pub fn to_json_line(f: &Finding, baselined: bool) -> String {
    Json::obj()
        .set("rule", f.rule)
        .set("file", f.file.as_str())
        .set("line", f.line)
        .set("baselined", baselined)
        .set("message", f.message.as_str())
        .render()
}

/// Everything `parrot lint` needs to report one run.
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub resolution: Resolution,
    pub unresolved: Vec<Unresolved>,
    pub n_fns: usize,
    pub n_edges: usize,
}

/// Analyze `repo_root` (which must contain `rust/src`) against the
/// baseline text.
pub fn lint_repo(repo_root: &Path, baseline_text: &str) -> Result<LintReport> {
    let analysis = run(repo_root)?;
    let base = Baseline::parse(baseline_text)?;
    let known: Vec<&str> = rules::RULES.iter().map(|r| r.name).collect();
    base.validate_rules(&known)?;
    let resolution = baseline::resolve(&analysis.findings, &base);
    Ok(LintReport {
        findings: analysis.findings,
        resolution,
        unresolved: analysis.unresolved,
        n_fns: analysis.n_fns,
        n_edges: analysis.n_edges,
    })
}

/// `parrot lint --explain RULE` — print a rule's policy card (or all
/// of them for `all`).
pub fn explain(rule: &str) -> Result<()> {
    fn card(r: &rules::RuleInfo) {
        println!("{}", r.name);
        println!("  scope: {}", r.scope);
        println!("  why:   {}", r.why);
        println!("  fix:   {}", r.fix);
    }
    if rule == "all" {
        for (i, r) in rules::RULES.iter().enumerate() {
            if i > 0 {
                println!();
            }
            card(r);
        }
        return Ok(());
    }
    match rules::rule_info(rule) {
        Some(r) => {
            card(r);
            Ok(())
        }
        None => bail!(
            "--explain {rule:?}: unknown rule — known rules: all, {}",
            rules::RULES.iter().map(|r| r.name).collect::<Vec<_>>().join(", ")
        ),
    }
}

/// The `parrot lint` subcommand body.
pub fn run_cli(
    root: &str,
    format: &str,
    baseline_path: &str,
    write_baseline: bool,
    out: Option<&str>,
) -> Result<()> {
    let repo_root = PathBuf::from(root);
    let base_file = repo_root.join(baseline_path);
    let baseline_text = match std::fs::read_to_string(&base_file) {
        Ok(t) => t,
        Err(_) => {
            eprintln!(
                "parrot lint: no baseline at {} — treating every finding as new",
                base_file.display()
            );
            String::new()
        }
    };
    let report = lint_repo(&repo_root, &baseline_text)?;

    if write_baseline {
        std::fs::write(&base_file, Baseline::render(&report.findings))
            .with_context(|| format!("write {}", base_file.display()))?;
        println!(
            "parrot lint: baseline rewritten with {} finding(s) across {} group(s) -> {}",
            report.findings.len(),
            baseline::count_by_group(&report.findings).len(),
            base_file.display()
        );
        return Ok(());
    }

    let is_violation = |f: &Finding| report.resolution.violations.contains(f);
    // JSON lines are always materialized: they feed `--format json`
    // *and* `--out` (CI archives the report regardless of the display
    // format).
    let json_lines: Vec<String> =
        report.findings.iter().map(|f| to_json_line(f, !is_violation(f))).collect();
    if let Some(path) = out {
        let mut body = json_lines.join("\n");
        if !body.is_empty() {
            body.push('\n');
        }
        std::fs::write(path, body).with_context(|| format!("write --out {path}"))?;
    }
    match format {
        "json" => {
            for line in &json_lines {
                println!("{line}");
            }
        }
        "human" => {
            for f in &report.findings {
                let tag = if is_violation(f) { "ERROR" } else { "baselined" };
                println!("[{tag}] {}:{} {}: {}", f.file, f.line, f.rule, f.message);
            }
        }
        other => bail!("--format {other:?}: expected `human` or `json`"),
    }
    if !report.unresolved.is_empty() {
        eprintln!(
            "parrot lint: {} call site(s) unresolved across {} fns / {} edges — \
             treated as unknown (their effects are NOT assumed clean):",
            report.unresolved.len(),
            report.n_fns,
            report.n_edges
        );
        for u in report.unresolved.iter().take(20) {
            eprintln!("  {}:{} `{}` — {}", u.file, u.line, u.call, u.reason);
        }
        if report.unresolved.len() > 20 {
            eprintln!("  … and {} more", report.unresolved.len() - 20);
        }
    }
    for (rule, file, allowed, actual) in &report.resolution.slack {
        eprintln!(
            "parrot lint: ratchet slack — {rule} in {file} is down to {actual} \
             (baseline {allowed}); tighten with --write-baseline"
        );
    }
    let n_new = report.resolution.violations.len();
    let n_base = report.findings.len() - n_new;
    if n_new > 0 {
        bail!(
            "parrot lint: {n_new} finding(s) not covered by the baseline \
             ({n_base} grandfathered) — fix them or, for deliberate debt, \
             regenerate with --write-baseline"
        );
    }
    println!("parrot lint: clean ({n_base} grandfathered finding(s))");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The whole pipeline over the real tree: the committed baseline
    /// must cover every finding — i.e. the determinism-critical
    /// modules are Hash*-free (directly and through helpers), ambient
    /// entropy stays in its two allowlisted files, every wire pair is
    /// symmetric, and no unchecked `.len() as u32` remains.
    #[test]
    fn repo_is_clean_under_committed_baseline() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let baseline_text = std::fs::read_to_string(root.join("lint.baseline"))
            .expect("committed lint.baseline");
        let report = lint_repo(root, &baseline_text).unwrap();
        assert!(
            report.resolution.violations.is_empty(),
            "non-baselined lint findings:\n{}",
            report
                .resolution
                .violations
                .iter()
                .map(|f| format!("  {}:{} {}: {}", f.file, f.line, f.rule, f.message))
                .collect::<Vec<_>>()
                .join("\n")
        );
        // The ratchet is fully paid down: *no* rule carries
        // grandfathered debt, so the committed lint.baseline must stay
        // empty (comments only) and every rule reports zero findings.
        assert!(
            report.findings.is_empty(),
            "lint.baseline must stay empty — grandfathered finding(s) reappeared:\n{}",
            report
                .findings
                .iter()
                .map(|f| format!("  {}:{} {}: {}", f.file, f.line, f.rule, f.message))
                .collect::<Vec<_>>()
                .join("\n")
        );
        let parsed = Baseline::parse(&baseline_text).expect("parse committed baseline");
        assert!(
            parsed.entries.is_empty(),
            "committed lint.baseline still grandfathers findings — delete the paid-down entries"
        );
    }

    /// An injected violation must come back as a non-baselined
    /// failure — this is the fixture self-test backing the ci.sh
    /// gate's "fails on injected violations" guarantee.
    #[test]
    fn injected_violation_fails_the_gate() {
        let dir = std::env::temp_dir().join(format!("parrot_lint_inject_{}", std::process::id()));
        let src = dir.join("rust").join("src").join("simulation");
        std::fs::create_dir_all(&src).unwrap();
        std::fs::write(
            src.join("mod.rs"),
            "use std::collections::HashMap;\npub fn bad(m: &HashMap<u64, u64>) -> usize {\n    m.len()\n}\n",
        )
        .unwrap();
        let report = lint_repo(&dir, "").unwrap();
        std::fs::remove_dir_all(&dir).ok();
        let rules: Vec<_> = report.resolution.violations.iter().map(|f| f.rule).collect();
        assert_eq!(rules, vec!["unordered-iter", "unordered-iter"]);
        assert_eq!(report.resolution.violations[0].line, 1);
        assert_eq!(report.resolution.violations[1].line, 2);
        // ...and the same findings are absorbed by a matching baseline.
        let absorbed = lint_repo(
            &std::env::temp_dir().join("nonexistent_parrot_lint"),
            "unordered-iter simulation/mod.rs 2\n",
        );
        assert!(absorbed.is_err()); // no rust/src there — just exercising the error path
    }

    #[test]
    fn json_lines_are_well_formed() {
        let f = Finding {
            rule: "unordered-iter",
            file: "simulation/mod.rs".into(),
            line: 7,
            message: "say \"no\" to\nunordered iteration".into(),
        };
        let line = to_json_line(&f, false);
        assert_eq!(
            line,
            "{\"rule\":\"unordered-iter\",\"file\":\"simulation/mod.rs\",\"line\":7,\
             \"baselined\":false,\"message\":\"say \\\"no\\\" to\\nunordered iteration\"}"
        );
    }

    /// Call-chain messages quote source with backticks, quotes, and
    /// backslashes — every emitted line must survive the util::json
    /// parser (the same one ci.sh's archived report is validated with).
    #[test]
    fn emitted_lines_parse_back_through_util_json() {
        let f = Finding {
            rule: "ambient-entropy-transitive",
            file: "simulation/mod.rs".into(),
            line: 419,
            message: "chain `a::b` -> `c` quoting \"raw \\ text\"\twith tabs".into(),
        };
        let line = to_json_line(&f, true);
        let parsed = crate::util::json::parse(&line).expect("emitted line must be valid JSON");
        assert_eq!(parsed.render(), line, "parse->render must round-trip the emitted line");
    }

    #[test]
    fn explain_knows_every_registered_rule_and_rejects_unknown() {
        for r in rules::RULES {
            explain(r.name).unwrap();
        }
        explain("all").unwrap();
        let err = explain("no-such-rule").unwrap_err().to_string();
        assert!(err.contains("unknown rule"), "{err}");
        assert!(err.contains("wire-asymmetry"), "error should list known rules: {err}");
    }
}
