//! `parrot lint` — a repo-local determinism & wire-safety
//! static-analysis pass.
//!
//! The ROADMAP's parallel-simulation step ("same seed ≡ same trace
//! across thread counts") is only attemptable with zero hidden
//! nondeterminism, and the sim==deploy differentials of PRs 3–5 place
//! the same obligation on the wire path.  This subsystem turns that
//! discipline from reviewer folklore into a CI gate:
//!
//!   * [`lexer`] — comment/string-stripping scanner over
//!     `rust/src/**/*.rs` recovering `#[cfg(test)]` regions and
//!     fn/impl spans (no external parser; the build is offline),
//!   * [`rules`] — the five rules and their module-scoped policy,
//!   * [`baseline`] — the committed grandfather file and its
//!     one-way ratchet.
//!
//! `parrot lint` emits human or JSON-lines output and exits nonzero
//! on any finding not covered by `lint.baseline`; `scripts/ci.sh`
//! runs it after the release build.

pub mod baseline;
pub mod lexer;
pub mod rules;

use anyhow::{bail, Context, Result};
use baseline::{Baseline, Resolution};
use rules::Finding;
use std::path::{Path, PathBuf};

/// Recursively collect `.rs` files under `dir`, sorted by relative
/// path so findings (and the rendered baseline) are order-stable.
fn collect_rs_files(dir: &Path) -> Result<Vec<PathBuf>> {
    fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
        let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
            .with_context(|| format!("read dir {}", dir.display()))?
            .map(|e| e.map(|e| e.path()))
            .collect::<std::io::Result<_>>()?;
        entries.sort();
        for p in entries {
            if p.is_dir() {
                walk(&p, out)?;
            } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
                out.push(p);
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    walk(dir, &mut out)?;
    Ok(out)
}

/// Run all rules over every `.rs` file under `src_root` (the
/// `rust/src` directory).  Findings are sorted by (file, line, rule).
pub fn run(src_root: &Path) -> Result<Vec<Finding>> {
    let mut findings = Vec::new();
    for path in collect_rs_files(src_root)? {
        let rel = path
            .strip_prefix(src_root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src =
            std::fs::read_to_string(&path).with_context(|| format!("read {}", path.display()))?;
        findings.extend(rules::check_file(&rel, &src));
    }
    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(findings)
}

/// Minimal JSON string escaping (offline build: no serde).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// One JSON-lines record per finding — the `--format json` output
/// consumed by CI tooling.
pub fn to_json_line(f: &Finding, baselined: bool) -> String {
    format!(
        "{{\"rule\":\"{}\",\"file\":\"{}\",\"line\":{},\"baselined\":{},\"message\":\"{}\"}}",
        json_escape(f.rule),
        json_escape(&f.file),
        f.line,
        baselined,
        json_escape(&f.message),
    )
}

/// Everything `parrot lint` needs to report one run.
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub resolution: Resolution,
}

/// Analyze `repo_root` (which must contain `rust/src`) against the
/// baseline text.
pub fn lint_repo(repo_root: &Path, baseline_text: &str) -> Result<LintReport> {
    let src_root = repo_root.join("rust").join("src");
    if !src_root.is_dir() {
        bail!("{} has no rust/src — pass the repo root via --root", repo_root.display());
    }
    let findings = run(&src_root)?;
    let base = Baseline::parse(baseline_text)?;
    let resolution = baseline::resolve(&findings, &base);
    Ok(LintReport { findings, resolution })
}

/// The `parrot lint` subcommand body.
pub fn run_cli(root: &str, format: &str, baseline_path: &str, write_baseline: bool) -> Result<()> {
    let repo_root = PathBuf::from(root);
    let base_file = repo_root.join(baseline_path);
    let baseline_text = match std::fs::read_to_string(&base_file) {
        Ok(t) => t,
        Err(_) => {
            eprintln!(
                "parrot lint: no baseline at {} — treating every finding as new",
                base_file.display()
            );
            String::new()
        }
    };
    let report = lint_repo(&repo_root, &baseline_text)?;

    if write_baseline {
        std::fs::write(&base_file, Baseline::render(&report.findings))
            .with_context(|| format!("write {}", base_file.display()))?;
        println!(
            "parrot lint: baseline rewritten with {} finding(s) across {} group(s) -> {}",
            report.findings.len(),
            baseline::count_by_group(&report.findings).len(),
            base_file.display()
        );
        return Ok(());
    }

    let is_violation = |f: &Finding| report.resolution.violations.contains(f);
    match format {
        "json" => {
            for f in &report.findings {
                println!("{}", to_json_line(f, !is_violation(f)));
            }
        }
        "human" => {
            for f in &report.findings {
                let tag = if is_violation(f) { "ERROR" } else { "baselined" };
                println!("[{tag}] {}:{} {}: {}", f.file, f.line, f.rule, f.message);
            }
        }
        other => bail!("--format {other:?}: expected `human` or `json`"),
    }
    for (rule, file, allowed, actual) in &report.resolution.slack {
        eprintln!(
            "parrot lint: ratchet slack — {rule} in {file} is down to {actual} \
             (baseline {allowed}); tighten with --write-baseline"
        );
    }
    let n_new = report.resolution.violations.len();
    let n_base = report.findings.len() - n_new;
    if n_new > 0 {
        bail!(
            "parrot lint: {n_new} finding(s) not covered by the baseline \
             ({n_base} grandfathered) — fix them or, for deliberate debt, \
             regenerate with --write-baseline"
        );
    }
    println!("parrot lint: clean ({n_base} grandfathered finding(s))");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The whole pipeline over the real tree: the committed baseline
    /// must cover every finding — i.e. the determinism-critical
    /// modules are Hash*-free, ambient entropy stays in its two
    /// allowlisted files, and no unchecked `.len() as u32` remains.
    #[test]
    fn repo_is_clean_under_committed_baseline() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let baseline_text = std::fs::read_to_string(root.join("lint.baseline"))
            .expect("committed lint.baseline");
        let report = lint_repo(root, &baseline_text).unwrap();
        assert!(
            report.resolution.violations.is_empty(),
            "non-baselined lint findings:\n{}",
            report
                .resolution
                .violations
                .iter()
                .map(|f| format!("  {}:{} {}: {}", f.file, f.line, f.rule, f.message))
                .collect::<Vec<_>>()
                .join("\n")
        );
        // The ratchet is fully paid down: *no* rule carries
        // grandfathered debt, so the committed lint.baseline must stay
        // empty (comments only) and every rule reports zero findings.
        assert!(
            report.findings.is_empty(),
            "lint.baseline must stay empty — grandfathered finding(s) reappeared:\n{}",
            report
                .findings
                .iter()
                .map(|f| format!("  {}:{} {}: {}", f.file, f.line, f.rule, f.message))
                .collect::<Vec<_>>()
                .join("\n")
        );
        let parsed = Baseline::parse(&baseline_text).expect("parse committed baseline");
        assert!(
            parsed.entries.is_empty(),
            "committed lint.baseline still grandfathers findings — delete the paid-down entries"
        );
    }

    /// An injected violation must come back as a non-baselined
    /// failure — this is the fixture self-test backing the ci.sh
    /// gate's "fails on injected violations" guarantee.
    #[test]
    fn injected_violation_fails_the_gate() {
        let dir = std::env::temp_dir().join(format!("parrot_lint_inject_{}", std::process::id()));
        let src = dir.join("rust").join("src").join("simulation");
        std::fs::create_dir_all(&src).unwrap();
        std::fs::write(
            src.join("mod.rs"),
            "use std::collections::HashMap;\npub fn bad(m: &HashMap<u64, u64>) -> usize {\n    m.len()\n}\n",
        )
        .unwrap();
        let report = lint_repo(&dir, "").unwrap();
        std::fs::remove_dir_all(&dir).ok();
        let rules: Vec<_> = report.resolution.violations.iter().map(|f| f.rule).collect();
        assert_eq!(rules, vec!["unordered-iter", "unordered-iter"]);
        assert_eq!(report.resolution.violations[0].line, 1);
        assert_eq!(report.resolution.violations[1].line, 2);
        // ...and the same findings are absorbed by a matching baseline.
        let absorbed = lint_repo(
            &std::env::temp_dir().join("nonexistent_parrot_lint"),
            "unordered-iter simulation/mod.rs 2\n",
        );
        assert!(absorbed.is_err()); // no rust/src there — just exercising the error path
    }

    #[test]
    fn json_lines_are_well_formed() {
        let f = Finding {
            rule: "unordered-iter",
            file: "simulation/mod.rs".into(),
            line: 7,
            message: "say \"no\" to\nunordered iteration".into(),
        };
        let line = to_json_line(&f, false);
        assert_eq!(
            line,
            "{\"rule\":\"unordered-iter\",\"file\":\"simulation/mod.rs\",\"line\":7,\
             \"baselined\":false,\"message\":\"say \\\"no\\\" to\\nunordered iteration\"}"
        );
    }
}
