//! The per-file `parrot lint` rules, their module-scoped policy, and
//! the registry (`RULES`) covering every rule the analyzer emits —
//! including the interprocedural ones implemented in `effects.rs` and
//! `wire.rs`.
//!
//! Per-file policy table (see README "Determinism discipline" for
//! rationale):
//!
//! | rule              | scope                                   | why |
//! |-------------------|-----------------------------------------|-----|
//! | `unordered-iter`  | determinism-critical modules            | Hash* iteration order reorders events/reductions |
//! | `ambient-entropy` | everywhere but `util/timer`,`util/bench`| wallclock/OS entropy breaks same-seed ≡ same-trace |
//! | `panicking-decode`| `Decoder` impls + decode fns            | hostile frames must error, not kill the server |
//! | `unchecked-narrow`| everywhere (+ config casts in strict)   | `len() as u32` truncates wire prefixes silently; `cfg.x as usize` wraps on fat configs |
//! | `float-order`     | `aggregation` merge paths               | float sums over Hash* collections are order-defined |
//!
//! Detection is deliberately textual-over-stripped-source (no type
//! inference): `unordered-iter` flags any `HashMap`/`HashSet` mention
//! in a strict module, because a Hash* collection in scope is one
//! `for` loop away from nondeterministic iteration — the fix the rule
//! demands (BTreeMap / sorted snapshot / indexed `Vec` table) removes
//! the mention itself. Test code (`#[cfg(test)]` regions) is exempt
//! everywhere: tests assert on sorted views and may build hostile
//! inputs however they like.

use super::lexer::{analyze_source, SourceMap};

/// Modules whose event/merge order is observable in traces; Hash*
/// containers are banned here outright.
pub const STRICT_MODULES: &[&str] =
    &["simulation", "scheduler", "aggregation", "statestore", "compress", "cluster", "obs"];

/// The only files allowed to touch wallclock/OS entropy: the
/// stopwatch used for *reporting* elapsed real time, and the bench
/// harness.  All simulation randomness goes through seeded
/// `util::rng::Rng`.
pub const ENTROPY_ALLOWLIST: &[&str] = &["util/timer.rs", "util/bench.rs"];

/// One rule violation at a source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    /// Path relative to the scanned source root, e.g. `statestore/lru.rs`.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub message: String,
}

/// Token patterns shared with the interprocedural pass
/// (`analysis/effects.rs` seeds per-fn effect bits from the same
/// rules, so direct and transitive findings can never disagree on
/// what counts as a violation).
pub(crate) const ENTROPY_PATTERNS: &[&str] =
    &["thread_rng", "from_entropy", "SystemTime::now", "Instant::now"];
pub(crate) const PANIC_PATTERNS: &[&str] =
    &[".unwrap()", ".expect(", "panic!(", "unreachable!("];
pub(crate) const FLOAT_ACCUM_PATTERNS: &[&str] = &[".sum::<f32>", ".sum::<f64>", ".fold("];

/// Top-level module of a source-root-relative path:
/// `statestore/lru.rs` → `statestore`; `lib.rs` → `lib`.
pub(crate) fn top_module(rel_path: &str) -> &str {
    match rel_path.split_once('/') {
        Some((m, _)) => m,
        None => rel_path.strip_suffix(".rs").unwrap_or(rel_path),
    }
}

pub(crate) fn word_in(line: &str, word: &str) -> bool {
    let b = line.as_bytes();
    let w = word.as_bytes();
    if b.len() < w.len() {
        return false;
    }
    for i in 0..=b.len() - w.len() {
        if &b[i..i + w.len()] == w {
            let pre_ok = i == 0 || !(b[i - 1].is_ascii_alphanumeric() || b[i - 1] == b'_');
            let post = i + w.len();
            let post_ok =
                post == b.len() || !(b[post].is_ascii_alphanumeric() || b[post] == b'_');
            if pre_ok && post_ok {
                return true;
            }
        }
    }
    false
}

fn rule_unordered_iter(rel: &str, map: &SourceMap, out: &mut Vec<Finding>) {
    if !STRICT_MODULES.contains(&top_module(rel)) {
        return;
    }
    for (i, line) in map.lines.iter().enumerate() {
        let ln = i + 1;
        if map.line_is_test(ln) {
            continue;
        }
        for ty in ["HashMap", "HashSet"] {
            if word_in(line, ty) {
                out.push(Finding {
                    rule: "unordered-iter",
                    file: rel.to_string(),
                    line: ln,
                    message: format!(
                        "{ty} in determinism-critical module `{}`: iteration order is \
                         nondeterministic — use BTreeMap, a sorted snapshot, or an \
                         indexed Vec table",
                        top_module(rel)
                    ),
                });
            }
        }
    }
}

fn rule_ambient_entropy(rel: &str, map: &SourceMap, out: &mut Vec<Finding>) {
    if ENTROPY_ALLOWLIST.contains(&rel) {
        return;
    }
    for (i, line) in map.lines.iter().enumerate() {
        let ln = i + 1;
        if map.line_is_test(ln) {
            continue;
        }
        for p in ENTROPY_PATTERNS {
            if line.contains(p) {
                out.push(Finding {
                    rule: "ambient-entropy",
                    file: rel.to_string(),
                    line: ln,
                    message: format!(
                        "`{p}` outside util/timer.rs+util/bench.rs: ambient entropy \
                         breaks same-seed ≡ same-trace — route through seeded \
                         util::rng::Rng / virtual time"
                    ),
                });
            }
        }
    }
}

/// Per-line decode-path scope: lines inside an `impl Decoder`/`impl
/// ... for Decoder` block, or inside a fn whose name marks it as a
/// decode path.  Shared with the transitive pass and the wire rules.
pub(crate) fn decode_scope(map: &SourceMap) -> Vec<bool> {
    let decode_fn = |name: &str| {
        name.starts_with("decode") || name.contains("from_bytes") || name.contains("from_le_bytes")
    };
    let mut in_scope = vec![false; map.lines.len()];
    for im in &map.impls {
        if im.type_name == "Decoder" || im.trait_name.as_deref() == Some("Decoder") {
            for l in im.start..=im.end.min(map.lines.len()) {
                in_scope[l - 1] = true;
            }
        }
    }
    for f in &map.fns {
        if decode_fn(&f.name) {
            for l in f.start..=f.end.min(map.lines.len()) {
                in_scope[l - 1] = true;
            }
        }
    }
    in_scope
}

fn rule_panicking_decode(rel: &str, map: &SourceMap, out: &mut Vec<Finding>) {
    let in_scope = decode_scope(map);
    for (i, line) in map.lines.iter().enumerate() {
        let ln = i + 1;
        if !in_scope[i] || map.line_is_test(ln) {
            continue;
        }
        for p in PANIC_PATTERNS {
            if line.contains(p) {
                out.push(Finding {
                    rule: "panicking-decode",
                    file: rel.to_string(),
                    line: ln,
                    message: format!(
                        "`{p}` on a decode path: wire input is untrusted — a hostile or \
                         truncated frame must surface as Err, not a panic",
                        p = p.trim_matches(|c| c == '.' || c == '(')
                    ),
                });
            }
        }
    }
}

/// Does `line` narrow a config-sourced integer with `as`?  Matches
/// `cfg.<field> as usize|u32|u16` with a word boundary before `cfg`
/// (the `.` in `self.cfg.x` is a boundary, `scfg.x` is not a match).
/// Config fields are u64-sized and operator-controlled, so the cast
/// silently wraps instead of erroring on oversized values.
fn cfg_narrow_in(line: &str) -> bool {
    let b = line.as_bytes();
    let mut i = 0;
    while let Some(p) = line[i..].find("cfg.") {
        let start = i + p;
        let pre_ok =
            start == 0 || !(b[start - 1].is_ascii_alphanumeric() || b[start - 1] == b'_');
        let mut j = start + 4;
        while j < b.len() && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
            j += 1;
        }
        if pre_ok
            && j > start + 4
            && [" as usize", " as u32", " as u16"].iter().any(|t| line[j..].starts_with(t))
        {
            return true;
        }
        i = start + 4;
    }
    false
}

fn rule_unchecked_narrow(rel: &str, map: &SourceMap, out: &mut Vec<Finding>) {
    let strict = STRICT_MODULES.contains(&top_module(rel));
    for (i, line) in map.lines.iter().enumerate() {
        let ln = i + 1;
        if map.line_is_test(ln) {
            continue;
        }
        for p in [".len() as u32", ".len() as u16"] {
            if line.contains(p) {
                out.push(Finding {
                    rule: "unchecked-narrow",
                    file: rel.to_string(),
                    line: ln,
                    message: format!(
                        "`{p}` truncates silently past 4 GiB (or 64 KiB) — use \
                         Encoder::put_len / Encoder::try_put_u32, which reject \
                         oversized lengths as Err",
                        p = p.trim_start_matches('.')
                    ),
                });
            }
        }
        if strict && cfg_narrow_in(line) {
            out.push(Finding {
                rule: "unchecked-narrow",
                file: rel.to_string(),
                line: ln,
                message: "config-sourced integer narrowed with `as` in a strict \
                          module: config fields are u64-sized, so the cast wraps \
                          silently on oversized values — use usize::try_from / \
                          u32::try_from and surface the failure"
                    .to_string(),
            });
        }
    }
}

fn rule_float_order(rel: &str, map: &SourceMap, out: &mut Vec<Finding>) {
    if top_module(rel) != "aggregation" {
        return;
    }
    // Per-fn: a float fold/sum is only order-stable if its source
    // collection is ordered.  Without type inference we approximate:
    // flag fold/sum lines in fns that also mention a Hash* container.
    for f in &map.fns {
        let lines = f.start..=f.end.min(map.lines.len());
        let mentions_hash = lines.clone().any(|l| {
            !map.line_is_test(l)
                && (word_in(&map.lines[l - 1], "HashMap") || word_in(&map.lines[l - 1], "HashSet"))
        });
        if !mentions_hash {
            continue;
        }
        for l in lines {
            if map.line_is_test(l) {
                continue;
            }
            if FLOAT_ACCUM_PATTERNS.iter().any(|p| map.lines[l - 1].contains(p)) {
                out.push(Finding {
                    rule: "float-order",
                    file: rel.to_string(),
                    line: l,
                    message: format!(
                        "float accumulation in `{}` alongside a Hash* collection: \
                         f32/f64 addition is not associative, so unordered sources \
                         make the merged value run-dependent — iterate an ordered view",
                        f.name
                    ),
                });
            }
        }
    }
}

/// Run the five per-file rules over an already-lexed file.  The
/// interprocedural rules (`*-transitive`, wire symmetry) live in
/// `effects.rs`/`wire.rs` and run over the whole loaded tree.
pub fn check_map(rel_path: &str, map: &SourceMap) -> Vec<Finding> {
    let mut out = Vec::new();
    rule_unordered_iter(rel_path, map, &mut out);
    rule_ambient_entropy(rel_path, map, &mut out);
    rule_panicking_decode(rel_path, map, &mut out);
    rule_unchecked_narrow(rel_path, map, &mut out);
    rule_float_order(rel_path, map, &mut out);
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// Run all five per-file rules over one file. `rel_path` is relative
/// to the scanned source root (`rust/src`), with `/` separators.
pub fn check_file(rel_path: &str, src: &str) -> Vec<Finding> {
    check_map(rel_path, &analyze_source(src))
}

/// Registry entry backing `parrot lint --explain RULE` and baseline
/// rule-name validation.
pub struct RuleInfo {
    pub name: &'static str,
    pub scope: &'static str,
    pub why: &'static str,
    pub fix: &'static str,
}

/// Every rule the analyzer can emit, per-file and interprocedural.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        name: "unordered-iter",
        scope: "determinism-critical modules (simulation, scheduler, aggregation, statestore, compress, cluster, obs)",
        why: "HashMap/HashSet iteration order is randomized per process; any event or merge order derived from it breaks same-seed == same-trace",
        fix: "use BTreeMap/BTreeSet, a sorted snapshot, or an indexed Vec table",
    },
    RuleInfo {
        name: "unordered-iter-transitive",
        scope: "call sites in determinism-critical modules whose callee (transitively) holds a Hash* container",
        why: "a strict module can launder nondeterministic iteration through a helper in a non-strict module; the per-file rule cannot see across the call",
        fix: "give the callee an ordered view (BTreeMap / sorted snapshot), or keep the call out of the engine",
    },
    RuleInfo {
        name: "ambient-entropy",
        scope: "everywhere except util/timer.rs and util/bench.rs",
        why: "wallclock/OS entropy makes runs non-replayable; simulation randomness must come from the seeded util::rng::Rng",
        fix: "route through seeded util::rng::Rng or virtual time",
    },
    RuleInfo {
        name: "ambient-entropy-transitive",
        scope: "call sites in determinism-critical modules whose callee (transitively) reads wallclock/OS entropy",
        why: "an engine-path helper that reads Instant::now/SystemTime::now smuggles real time beneath the deterministic engine even when the engine file itself is clean",
        fix: "inject the clock from the caller that consumes it (fn-pointer clock), so the engine path stays entropy-free",
    },
    RuleInfo {
        name: "panicking-decode",
        scope: "Decoder impls and decode/from_bytes fns",
        why: "wire input is untrusted: a hostile or truncated frame must surface as Err, not kill the server",
        fix: "replace unwrap/expect/panic with `?` and typed errors",
    },
    RuleInfo {
        name: "panicking-decode-transitive",
        scope: "call sites on decode paths whose callee (transitively) can panic",
        why: "a decode fn that carefully returns Err still dies if a helper it calls unwraps on the same untrusted bytes",
        fix: "make the helper return Result and propagate with `?`",
    },
    RuleInfo {
        name: "unchecked-narrow",
        scope: "everywhere for `.len() as u32/u16`; strict modules additionally for `cfg.<field> as usize/u32/u16`",
        why: "`.len() as u32/u16` silently truncates past 4 GiB / 64 KiB, corrupting wire length prefixes; config-sourced casts wrap silently on oversized operator input",
        fix: "use Encoder::put_len / Encoder::try_put_u32 for lengths, usize::try_from / u32::try_from for config fields",
    },
    RuleInfo {
        name: "float-order",
        scope: "aggregation merge paths",
        why: "f32/f64 addition is not associative, so summing over an unordered source makes the merged value run-dependent",
        fix: "iterate an ordered view before folding",
    },
    RuleInfo {
        name: "wire-asymmetry",
        scope: "every encode_*/decode_* pair (by impl type or file + name suffix), including per-tag Msg::encode/Msg::decode arms",
        why: "sim==deploy rides on the framed protocol: a width or order mismatch between writer and reader corrupts every field after it",
        fix: "mirror field order and widths exactly; put_len/try_put_u32 and u32()/count() are the same 4-byte opcode",
    },
    RuleInfo {
        name: "unguarded-len-alloc",
        scope: "decode paths",
        why: "an attacker-controlled length prefix driving Vec::with_capacity lets a single hostile frame allocate gigabytes",
        fix: "bound the length first (ensure!/charge_dense/Decoder::count) before allocating",
    },
    RuleInfo {
        name: "unfuzzed-variant",
        scope: "the Msg enum vs rust/tests/fuzz_decode.rs::sample_msgs",
        why: "the fuzz round-trip suite only defends variants it constructs; a new variant outside the sample pool ships with zero hostile-input coverage",
        fix: "add the variant to sample_msgs",
    },
];

/// Look up a rule by name in the registry.
pub fn rule_info(name: &str) -> Option<&'static RuleInfo> {
    RULES.iter().find(|r| r.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    const FIXTURE_STRICT: &str = "\
use std::collections::HashMap;

pub fn plan(sizes: &HashMap<usize, usize>) -> usize {
    let mut total = 0;
    for (_, s) in sizes.iter() {
        total += s;
    }
    total
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn test_ok() {
        let m: HashMap<usize, usize> = HashMap::new();
        assert_eq!(m.len(), 0);
    }
}
";

    #[test]
    fn unordered_iter_flags_strict_module_not_tests() {
        let f = check_file("simulation/fake.rs", FIXTURE_STRICT);
        let hits: Vec<usize> =
            f.iter().filter(|x| x.rule == "unordered-iter").map(|x| x.line).collect();
        // line 1 (use) and line 3 (signature); the test-module mentions
        // on lines 13 and 17 are exempt.
        assert_eq!(hits, vec![1, 3]);
    }

    #[test]
    fn unordered_iter_covers_obs() {
        // The trace/metrics layer feeds byte-compared artifacts: obs is
        // a strict root like the engine itself.
        let f = check_file("obs/fake.rs", FIXTURE_STRICT);
        let hits: Vec<usize> =
            f.iter().filter(|x| x.rule == "unordered-iter").map(|x| x.line).collect();
        assert_eq!(hits, vec![1, 3]);
    }

    #[test]
    fn ambient_entropy_covers_obs() {
        let src = "fn stamp() -> u64 {\n    let t = std::time::Instant::now();\n    0\n}\n";
        let f = check_file("obs/chrome.rs", src);
        assert_eq!(f.iter().filter(|x| x.rule == "ambient-entropy").count(), 1);
    }

    #[test]
    fn unordered_iter_ignores_non_strict_modules() {
        assert!(check_file("transport/fake.rs", FIXTURE_STRICT)
            .iter()
            .all(|x| x.rule != "unordered-iter"));
    }

    #[test]
    fn ambient_entropy_flags_everywhere_but_allowlist() {
        let src = "fn seed() -> u64 {\n    let t = std::time::Instant::now();\n    0\n}\n";
        let f = check_file("coordinator/fake.rs", src);
        assert_eq!(f.iter().filter(|x| x.rule == "ambient-entropy").count(), 1);
        assert_eq!(f[0].line, 2);
        assert!(check_file("util/timer.rs", src).is_empty());
    }

    #[test]
    fn panicking_decode_scopes_to_decoder_impls_and_decode_fns() {
        let src = "\
impl<'a> Decoder<'a> {
    pub fn u32(&mut self) -> u32 {
        self.take(4).try_into().unwrap()
    }
}
pub fn decode_header(b: &[u8]) -> u8 {
    b.first().copied().expect(\"empty\")
}
pub fn encode_header(v: u8) -> Vec<u8> {
    let x: Option<u8> = Some(v);
    vec![x.unwrap()]
}
";
        let f = check_file("util/fake.rs", src);
        let hits: Vec<usize> =
            f.iter().filter(|x| x.rule == "panicking-decode").map(|x| x.line).collect();
        // line 3 (Decoder impl) + line 7 (decode_* fn); the unwrap in
        // encode_header (line 11) is out of scope.
        assert_eq!(hits, vec![3, 7]);
    }

    #[test]
    fn unchecked_narrow_flags_len_casts_with_span_accuracy() {
        let src = "fn put(e: &mut E, xs: &[f32]) {\n    e.put_u32(xs.len() as u32);\n    e.put_u16(xs.len() as u16);\n    e.put_u32(xs.len().try_into().unwrap());\n}\n";
        let f = check_file("model/fake.rs", src);
        let hits: Vec<usize> =
            f.iter().filter(|x| x.rule == "unchecked-narrow").map(|x| x.line).collect();
        assert_eq!(hits, vec![2, 3]);
    }

    #[test]
    fn unchecked_narrow_flags_config_casts_in_strict_modules_only() {
        let src = "\
fn plan(&self) -> usize {
    let b = cfg.state_bytes as usize;
    let w = self.cfg.shards as u32;
    let f = cfg.bandwidth as f64;
    let ok = usize::try_from(cfg.state_bytes);
    b
}
";
        let f = check_file("statestore/fake.rs", src);
        let hits: Vec<usize> =
            f.iter().filter(|x| x.rule == "unchecked-narrow").map(|x| x.line).collect();
        // lines 2 and 3 narrow config fields; `as f64` (line 4) widens
        // and try_from (line 5) is the demanded fix.
        assert_eq!(hits, vec![2, 3]);
        // Outside strict modules config casts stay legal (exp sweeps
        // cast clamped sweep axes all over).
        assert!(check_file("exp/fake.rs", src)
            .iter()
            .all(|x| x.rule != "unchecked-narrow"));
        // `scfg.` is not a config-field access.
        let near = "fn f() -> usize {\n    scfg.bytes as usize\n}\n";
        assert!(check_file("statestore/fake.rs", near).is_empty());
    }

    #[test]
    fn float_order_needs_hash_source_and_aggregation_module() {
        let src = "\
use std::collections::HashMap;
fn merge(weights: &HashMap<u64, f64>) -> f64 {
    weights.values().sum::<f64>()
}
fn stable(weights: &[f64]) -> f64 {
    weights.iter().sum::<f64>()
}
";
        let f = check_file("aggregation/fake.rs", src);
        let hits: Vec<usize> =
            f.iter().filter(|x| x.rule == "float-order").map(|x| x.line).collect();
        assert_eq!(hits, vec![3]);
        // same code outside aggregation: no float-order findings
        assert!(check_file("exp/fake.rs", src).iter().all(|x| x.rule != "float-order"));
    }

    #[test]
    fn violations_in_comments_and_strings_are_invisible() {
        let src = "// HashMap iteration would be bad\nfn f() -> &'static str {\n    \"thread_rng .len() as u32\"\n}\n";
        assert!(check_file("simulation/fake.rs", src).is_empty());
    }
}
