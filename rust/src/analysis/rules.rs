//! The five `parrot lint` rules and their module-scoped policy.
//!
//! Policy table (see README "Determinism discipline" for rationale):
//!
//! | rule              | scope                                   | why |
//! |-------------------|-----------------------------------------|-----|
//! | `unordered-iter`  | determinism-critical modules            | Hash* iteration order reorders events/reductions |
//! | `ambient-entropy` | everywhere but `util/timer`,`util/bench`| wallclock/OS entropy breaks same-seed ≡ same-trace |
//! | `panicking-decode`| `Decoder` impls + decode fns            | hostile frames must error, not kill the server |
//! | `unchecked-narrow`| everywhere                              | `len() as u32` truncates wire prefixes silently |
//! | `float-order`     | `aggregation` merge paths               | float sums over Hash* collections are order-defined |
//!
//! Detection is deliberately textual-over-stripped-source (no type
//! inference): `unordered-iter` flags any `HashMap`/`HashSet` mention
//! in a strict module, because a Hash* collection in scope is one
//! `for` loop away from nondeterministic iteration — the fix the rule
//! demands (BTreeMap / sorted snapshot / indexed `Vec` table) removes
//! the mention itself. Test code (`#[cfg(test)]` regions) is exempt
//! everywhere: tests assert on sorted views and may build hostile
//! inputs however they like.

use super::lexer::{analyze_source, SourceMap};

/// Modules whose event/merge order is observable in traces; Hash*
/// containers are banned here outright.
pub const STRICT_MODULES: &[&str] =
    &["simulation", "scheduler", "aggregation", "statestore", "compress", "cluster", "obs"];

/// The only files allowed to touch wallclock/OS entropy: the
/// stopwatch used for *reporting* elapsed real time, and the bench
/// harness.  All simulation randomness goes through seeded
/// `util::rng::Rng`.
pub const ENTROPY_ALLOWLIST: &[&str] = &["util/timer.rs", "util/bench.rs"];

/// One rule violation at a source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    /// Path relative to the scanned source root, e.g. `statestore/lru.rs`.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub message: String,
}

/// Top-level module of a source-root-relative path:
/// `statestore/lru.rs` → `statestore`; `lib.rs` → `lib`.
fn top_module(rel_path: &str) -> &str {
    match rel_path.split_once('/') {
        Some((m, _)) => m,
        None => rel_path.strip_suffix(".rs").unwrap_or(rel_path),
    }
}

fn word_in(line: &str, word: &str) -> bool {
    let b = line.as_bytes();
    let w = word.as_bytes();
    if b.len() < w.len() {
        return false;
    }
    for i in 0..=b.len() - w.len() {
        if &b[i..i + w.len()] == w {
            let pre_ok = i == 0 || !(b[i - 1].is_ascii_alphanumeric() || b[i - 1] == b'_');
            let post = i + w.len();
            let post_ok =
                post == b.len() || !(b[post].is_ascii_alphanumeric() || b[post] == b'_');
            if pre_ok && post_ok {
                return true;
            }
        }
    }
    false
}

fn rule_unordered_iter(rel: &str, map: &SourceMap, out: &mut Vec<Finding>) {
    if !STRICT_MODULES.contains(&top_module(rel)) {
        return;
    }
    for (i, line) in map.lines.iter().enumerate() {
        let ln = i + 1;
        if map.line_is_test(ln) {
            continue;
        }
        for ty in ["HashMap", "HashSet"] {
            if word_in(line, ty) {
                out.push(Finding {
                    rule: "unordered-iter",
                    file: rel.to_string(),
                    line: ln,
                    message: format!(
                        "{ty} in determinism-critical module `{}`: iteration order is \
                         nondeterministic — use BTreeMap, a sorted snapshot, or an \
                         indexed Vec table",
                        top_module(rel)
                    ),
                });
            }
        }
    }
}

fn rule_ambient_entropy(rel: &str, map: &SourceMap, out: &mut Vec<Finding>) {
    if ENTROPY_ALLOWLIST.contains(&rel) {
        return;
    }
    const PATTERNS: &[&str] =
        &["thread_rng", "from_entropy", "SystemTime::now", "Instant::now"];
    for (i, line) in map.lines.iter().enumerate() {
        let ln = i + 1;
        if map.line_is_test(ln) {
            continue;
        }
        for p in PATTERNS {
            if line.contains(p) {
                out.push(Finding {
                    rule: "ambient-entropy",
                    file: rel.to_string(),
                    line: ln,
                    message: format!(
                        "`{p}` outside util/timer.rs+util/bench.rs: ambient entropy \
                         breaks same-seed ≡ same-trace — route through seeded \
                         util::rng::Rng / virtual time"
                    ),
                });
            }
        }
    }
}

fn rule_panicking_decode(rel: &str, map: &SourceMap, out: &mut Vec<Finding>) {
    // Scope: lines inside an `impl Decoder`/`impl ... for Decoder`
    // block, or inside a fn whose name marks it as a decode path.
    let decode_fn = |name: &str| {
        name.starts_with("decode") || name.contains("from_bytes") || name.contains("from_le_bytes")
    };
    let mut in_scope = vec![false; map.lines.len()];
    for im in &map.impls {
        if im.type_name == "Decoder" || im.trait_name.as_deref() == Some("Decoder") {
            for l in im.start..=im.end.min(map.lines.len()) {
                in_scope[l - 1] = true;
            }
        }
    }
    for f in &map.fns {
        if decode_fn(&f.name) {
            for l in f.start..=f.end.min(map.lines.len()) {
                in_scope[l - 1] = true;
            }
        }
    }
    const PATTERNS: &[&str] = &[".unwrap()", ".expect(", "panic!(", "unreachable!("];
    for (i, line) in map.lines.iter().enumerate() {
        let ln = i + 1;
        if !in_scope[i] || map.line_is_test(ln) {
            continue;
        }
        for p in PATTERNS {
            if line.contains(p) {
                out.push(Finding {
                    rule: "panicking-decode",
                    file: rel.to_string(),
                    line: ln,
                    message: format!(
                        "`{p}` on a decode path: wire input is untrusted — a hostile or \
                         truncated frame must surface as Err, not a panic",
                        p = p.trim_matches(|c| c == '.' || c == '(')
                    ),
                });
            }
        }
    }
}

fn rule_unchecked_narrow(rel: &str, map: &SourceMap, out: &mut Vec<Finding>) {
    for (i, line) in map.lines.iter().enumerate() {
        let ln = i + 1;
        if map.line_is_test(ln) {
            continue;
        }
        for p in [".len() as u32", ".len() as u16"] {
            if line.contains(p) {
                out.push(Finding {
                    rule: "unchecked-narrow",
                    file: rel.to_string(),
                    line: ln,
                    message: format!(
                        "`{p}` truncates silently past 4 GiB (or 64 KiB) — use \
                         Encoder::put_len / Encoder::try_put_u32, which reject \
                         oversized lengths as Err",
                        p = p.trim_start_matches('.')
                    ),
                });
            }
        }
    }
}

fn rule_float_order(rel: &str, map: &SourceMap, out: &mut Vec<Finding>) {
    if top_module(rel) != "aggregation" {
        return;
    }
    // Per-fn: a float fold/sum is only order-stable if its source
    // collection is ordered.  Without type inference we approximate:
    // flag fold/sum lines in fns that also mention a Hash* container.
    const ACCUM: &[&str] = &[".sum::<f32>", ".sum::<f64>", ".fold("];
    for f in &map.fns {
        let lines = f.start..=f.end.min(map.lines.len());
        let mentions_hash = lines.clone().any(|l| {
            !map.line_is_test(l)
                && (word_in(&map.lines[l - 1], "HashMap") || word_in(&map.lines[l - 1], "HashSet"))
        });
        if !mentions_hash {
            continue;
        }
        for l in lines {
            if map.line_is_test(l) {
                continue;
            }
            if ACCUM.iter().any(|p| map.lines[l - 1].contains(p)) {
                out.push(Finding {
                    rule: "float-order",
                    file: rel.to_string(),
                    line: l,
                    message: format!(
                        "float accumulation in `{}` alongside a Hash* collection: \
                         f32/f64 addition is not associative, so unordered sources \
                         make the merged value run-dependent — iterate an ordered view",
                        f.name
                    ),
                });
            }
        }
    }
}

/// Run all five rules over one file. `rel_path` is relative to the
/// scanned source root (`rust/src`), with `/` separators.
pub fn check_file(rel_path: &str, src: &str) -> Vec<Finding> {
    let map = analyze_source(src);
    let mut out = Vec::new();
    rule_unordered_iter(rel_path, &map, &mut out);
    rule_ambient_entropy(rel_path, &map, &mut out);
    rule_panicking_decode(rel_path, &map, &mut out);
    rule_unchecked_narrow(rel_path, &map, &mut out);
    rule_float_order(rel_path, &map, &mut out);
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const FIXTURE_STRICT: &str = "\
use std::collections::HashMap;

pub fn plan(sizes: &HashMap<usize, usize>) -> usize {
    let mut total = 0;
    for (_, s) in sizes.iter() {
        total += s;
    }
    total
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn test_ok() {
        let m: HashMap<usize, usize> = HashMap::new();
        assert_eq!(m.len(), 0);
    }
}
";

    #[test]
    fn unordered_iter_flags_strict_module_not_tests() {
        let f = check_file("simulation/fake.rs", FIXTURE_STRICT);
        let hits: Vec<usize> =
            f.iter().filter(|x| x.rule == "unordered-iter").map(|x| x.line).collect();
        // line 1 (use) and line 3 (signature); the test-module mentions
        // on lines 13 and 17 are exempt.
        assert_eq!(hits, vec![1, 3]);
    }

    #[test]
    fn unordered_iter_covers_obs() {
        // The trace/metrics layer feeds byte-compared artifacts: obs is
        // a strict root like the engine itself.
        let f = check_file("obs/fake.rs", FIXTURE_STRICT);
        let hits: Vec<usize> =
            f.iter().filter(|x| x.rule == "unordered-iter").map(|x| x.line).collect();
        assert_eq!(hits, vec![1, 3]);
    }

    #[test]
    fn ambient_entropy_covers_obs() {
        let src = "fn stamp() -> u64 {\n    let t = std::time::Instant::now();\n    0\n}\n";
        let f = check_file("obs/chrome.rs", src);
        assert_eq!(f.iter().filter(|x| x.rule == "ambient-entropy").count(), 1);
    }

    #[test]
    fn unordered_iter_ignores_non_strict_modules() {
        assert!(check_file("transport/fake.rs", FIXTURE_STRICT)
            .iter()
            .all(|x| x.rule != "unordered-iter"));
    }

    #[test]
    fn ambient_entropy_flags_everywhere_but_allowlist() {
        let src = "fn seed() -> u64 {\n    let t = std::time::Instant::now();\n    0\n}\n";
        let f = check_file("coordinator/fake.rs", src);
        assert_eq!(f.iter().filter(|x| x.rule == "ambient-entropy").count(), 1);
        assert_eq!(f[0].line, 2);
        assert!(check_file("util/timer.rs", src).is_empty());
    }

    #[test]
    fn panicking_decode_scopes_to_decoder_impls_and_decode_fns() {
        let src = "\
impl<'a> Decoder<'a> {
    pub fn u32(&mut self) -> u32 {
        self.take(4).try_into().unwrap()
    }
}
pub fn decode_header(b: &[u8]) -> u8 {
    b.first().copied().expect(\"empty\")
}
pub fn encode_header(v: u8) -> Vec<u8> {
    let x: Option<u8> = Some(v);
    vec![x.unwrap()]
}
";
        let f = check_file("util/fake.rs", src);
        let hits: Vec<usize> =
            f.iter().filter(|x| x.rule == "panicking-decode").map(|x| x.line).collect();
        // line 3 (Decoder impl) + line 7 (decode_* fn); the unwrap in
        // encode_header (line 11) is out of scope.
        assert_eq!(hits, vec![3, 7]);
    }

    #[test]
    fn unchecked_narrow_flags_len_casts_with_span_accuracy() {
        let src = "fn put(e: &mut E, xs: &[f32]) {\n    e.put_u32(xs.len() as u32);\n    e.put_u16(xs.len() as u16);\n    e.put_u32(xs.len().try_into().unwrap());\n}\n";
        let f = check_file("model/fake.rs", src);
        let hits: Vec<usize> =
            f.iter().filter(|x| x.rule == "unchecked-narrow").map(|x| x.line).collect();
        assert_eq!(hits, vec![2, 3]);
    }

    #[test]
    fn float_order_needs_hash_source_and_aggregation_module() {
        let src = "\
use std::collections::HashMap;
fn merge(weights: &HashMap<u64, f64>) -> f64 {
    weights.values().sum::<f64>()
}
fn stable(weights: &[f64]) -> f64 {
    weights.iter().sum::<f64>()
}
";
        let f = check_file("aggregation/fake.rs", src);
        let hits: Vec<usize> =
            f.iter().filter(|x| x.rule == "float-order").map(|x| x.line).collect();
        assert_eq!(hits, vec![3]);
        // same code outside aggregation: no float-order findings
        assert!(check_file("exp/fake.rs", src).iter().all(|x| x.rule != "float-order"));
    }

    #[test]
    fn violations_in_comments_and_strings_are_invisible() {
        let src = "// HashMap iteration would be bad\nfn f() -> &'static str {\n    \"thread_rng .len() as u32\"\n}\n";
        assert!(check_file("simulation/fake.rs", src).is_empty());
    }
}
