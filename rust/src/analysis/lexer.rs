//! Source lexer for `parrot lint` — comment/string stripping plus
//! span tracking, with no external parser dependency (DESIGN.md §6:
//! the build is fully offline, so `syn` is not an option).
//!
//! The model is deliberately sub-AST: rules match on *stripped* source
//! text (comments and literal contents blanked to spaces, line
//! structure preserved), scoped by three facts this file recovers:
//!
//!   * which lines sit inside a `#[cfg(test)]` item (test code is
//!     exempt from most rules),
//!   * the brace-matched span and name of every `fn`,
//!   * the brace-matched span, self-type and trait of every `impl`.
//!
//! That is enough to express all five determinism/wire-safety rules
//! without type inference, and it keeps the analyzer honest: anything
//! it cannot see (macro-generated code) is out of scope by
//! construction, not silently half-checked.

/// A brace-matched `fn` item: 1-based inclusive line span.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FnSpan {
    pub name: String,
    pub start: usize,
    pub end: usize,
}

/// A brace-matched `impl` block: `impl Type` or `impl Trait for Type`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ImplSpan {
    pub type_name: String,
    pub trait_name: Option<String>,
    pub start: usize,
    pub end: usize,
}

/// One analyzed source file.
pub struct SourceMap {
    /// Stripped source split into lines (same count as the input).
    pub lines: Vec<String>,
    /// `is_test[i]` — line `i+1` is inside a `#[cfg(test)]` item.
    pub is_test: Vec<bool>,
    pub fns: Vec<FnSpan>,
    pub impls: Vec<ImplSpan>,
}

impl SourceMap {
    /// Is 1-based `line` inside test-only code?
    pub fn line_is_test(&self, line: usize) -> bool {
        self.is_test.get(line.wrapping_sub(1)).copied().unwrap_or(false)
    }
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Blank comments and literal contents to spaces, preserving byte
/// positions and newlines so line/column arithmetic stays valid.
pub fn strip(src: &str) -> String {
    let b = src.as_bytes();
    let mut out = b.to_vec();
    let mut i = 0usize;
    while i < b.len() {
        match b[i] {
            b'/' if i + 1 < b.len() && b[i + 1] == b'/' => {
                // line comment
                while i < b.len() && b[i] != b'\n' {
                    out[i] = b' ';
                    i += 1;
                }
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'*' => {
                // block comment — Rust block comments nest
                let mut depth = 0usize;
                while i < b.len() {
                    if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                        depth += 1;
                        out[i] = b' ';
                        out[i + 1] = b' ';
                        i += 2;
                    } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                        depth -= 1;
                        out[i] = b' ';
                        out[i + 1] = b' ';
                        i += 2;
                        if depth == 0 {
                            break;
                        }
                    } else {
                        if b[i] != b'\n' {
                            out[i] = b' ';
                        }
                        i += 1;
                    }
                }
            }
            b'"' => {
                // plain (or raw, if preceded by r/#) string literal;
                // raw-ness only changes the terminator.
                let mut hashes = 0usize;
                let mut j = i;
                while j > 0 && b[j - 1] == b'#' {
                    hashes += 1;
                    j -= 1;
                }
                // bare r"..." — make sure the r is not the tail of an
                // identifier (`var"` is not valid Rust anyway, keep
                // the check cheap).
                let prefix_r = j > 0 && b[j - 1] == b'r';
                let r_own_token = j < 2 || !is_ident(b[j - 2]);
                let raw = prefix_r && (hashes > 0 || r_own_token);
                out[i] = b' ';
                i += 1;
                while i < b.len() {
                    if raw {
                        if b[i] == b'"' {
                            let mut k = 0usize;
                            while k < hashes && i + 1 + k < b.len() && b[i + 1 + k] == b'#' {
                                k += 1;
                            }
                            if k == hashes {
                                out[i] = b' ';
                                i += 1 + hashes;
                                break;
                            }
                        }
                    } else if b[i] == b'\\' && i + 1 < b.len() {
                        out[i] = b' ';
                        if b[i + 1] != b'\n' {
                            out[i + 1] = b' ';
                        }
                        i += 2;
                        continue;
                    } else if b[i] == b'"' {
                        out[i] = b' ';
                        i += 1;
                        break;
                    }
                    if b[i] != b'\n' {
                        out[i] = b' ';
                    }
                    i += 1;
                }
            }
            b'\'' => {
                // lifetime (`'a`) vs char literal (`'a'`, `'\n'`):
                // a lifetime is `'` + ident NOT followed by a closing
                // quote right after one ident char.
                let is_lifetime = i + 1 < b.len()
                    && (b[i + 1].is_ascii_alphabetic() || b[i + 1] == b'_')
                    && !(i + 2 < b.len() && b[i + 2] == b'\'');
                if is_lifetime {
                    i += 1;
                    while i < b.len() && is_ident(b[i]) {
                        i += 1;
                    }
                } else {
                    out[i] = b' ';
                    i += 1;
                    while i < b.len() {
                        if b[i] == b'\\' && i + 1 < b.len() {
                            out[i] = b' ';
                            out[i + 1] = b' ';
                            i += 2;
                            continue;
                        }
                        if b[i] == b'\'' {
                            out[i] = b' ';
                            i += 1;
                            break;
                        }
                        if b[i] != b'\n' {
                            out[i] = b' ';
                        }
                        i += 1;
                    }
                }
            }
            _ => i += 1,
        }
    }
    // The replacement is byte-for-byte ASCII spaces over a valid UTF-8
    // input, so the result stays valid UTF-8.
    String::from_utf8(out).expect("strip preserves utf8")
}

/// 1-based line number of byte offset `pos` given sorted line starts.
fn line_of(line_starts: &[usize], pos: usize) -> usize {
    match line_starts.binary_search(&pos) {
        Ok(i) => i + 1,
        Err(i) => i,
    }
}

/// Offset of the matching `}` for the `{` at `open` (stripped text, so
/// braces inside literals/comments are already gone). Returns the last
/// byte on unbalanced input instead of failing — a truncated file
/// still gets best-effort spans.
fn match_brace(flat: &[u8], open: usize) -> usize {
    let mut depth = 0usize;
    let mut i = open;
    while i < flat.len() {
        match flat[i] {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return i;
                }
            }
            _ => {}
        }
        i += 1;
    }
    flat.len().saturating_sub(1)
}

/// Next `{` or `;` at/after `from` — whichever comes first decides
/// whether the item has a body.
fn body_or_semi(flat: &[u8], from: usize) -> Option<(usize, bool)> {
    let mut i = from;
    while i < flat.len() {
        match flat[i] {
            b'{' => return Some((i, true)),
            b';' => return Some((i, false)),
            _ => i += 1,
        }
    }
    None
}

/// Word occurrences of `kw` in `flat` (ident-boundary checked).
fn keyword_positions(flat: &[u8], kw: &str) -> Vec<usize> {
    let k = kw.as_bytes();
    let mut out = Vec::new();
    if flat.len() < k.len() {
        return out;
    }
    for i in 0..=flat.len() - k.len() {
        if &flat[i..i + k.len()] == k
            && (i == 0 || !is_ident(flat[i - 1]))
            && (i + k.len() == flat.len() || !is_ident(flat[i + k.len()]))
        {
            out.push(i);
        }
    }
    out
}

/// Last path segment of a type/trait expression: `a::b::C<'x>` → `C`.
fn last_segment(expr: &str) -> String {
    let head = expr.split('<').next().unwrap_or("").trim();
    head.rsplit("::").next().unwrap_or("").trim().to_string()
}

/// Split an impl header (text between `impl` and the body `{`) into
/// (trait, self type), skipping leading generics.
fn parse_impl_header(header: &str) -> (Option<String>, String) {
    let mut rest = header.trim();
    if let Some(stripped) = rest.strip_prefix('<') {
        // skip the generic parameter list by angle-bracket matching
        let mut depth = 1usize;
        let mut cut = stripped.len();
        for (i, c) in stripped.char_indices() {
            match c {
                '<' => depth += 1,
                '>' => {
                    depth -= 1;
                    if depth == 0 {
                        cut = i + 1;
                        break;
                    }
                }
                _ => {}
            }
        }
        rest = stripped[cut..].trim();
    }
    // drop a trailing where-clause
    if let Some(w) = rest.find(" where ") {
        rest = rest[..w].trim();
    }
    match rest.split_once(" for ") {
        Some((tr, ty)) => (Some(last_segment(tr)), last_segment(ty)),
        None => (None, last_segment(rest)),
    }
}

/// Full per-file analysis: strip, then recover test regions and
/// fn/impl spans.
pub fn analyze_source(src: &str) -> SourceMap {
    let stripped = strip(src);
    let flat = stripped.as_bytes();
    let lines: Vec<String> = stripped.split('\n').map(|s| s.to_string()).collect();
    let mut line_starts = vec![0usize];
    for (i, &c) in flat.iter().enumerate() {
        if c == b'\n' {
            line_starts.push(i + 1);
        }
    }

    let mut is_test = vec![false; lines.len()];
    for pos in keyword_positions(flat, "cfg") {
        // match the exact `#[cfg(test)]` attribute shape (repo style)
        let tail = &stripped[pos..];
        if !tail.starts_with("cfg(test)") {
            continue;
        }
        if let Some((body, has_body)) = body_or_semi(flat, pos) {
            let end = if has_body { match_brace(flat, body) } else { body };
            let (a, b) = (line_of(&line_starts, pos), line_of(&line_starts, end));
            for l in a..=b {
                if l >= 1 && l <= is_test.len() {
                    is_test[l - 1] = true;
                }
            }
        }
    }

    let mut fns = Vec::new();
    for pos in keyword_positions(flat, "fn") {
        let mut i = pos + 2;
        while i < flat.len() && (flat[i] as char).is_whitespace() {
            i += 1;
        }
        let name_start = i;
        while i < flat.len() && is_ident(flat[i]) {
            i += 1;
        }
        if i == name_start {
            continue; // `fn` in a closure type like `Fn()` is boundary-checked out already
        }
        let name = stripped[name_start..i].to_string();
        if let Some((body, true)) = body_or_semi(flat, i) {
            let end = match_brace(flat, body);
            fns.push(FnSpan {
                name,
                start: line_of(&line_starts, pos),
                end: line_of(&line_starts, end),
            });
        }
    }

    let mut impls = Vec::new();
    for pos in keyword_positions(flat, "impl") {
        if let Some((body, true)) = body_or_semi(flat, pos + 4) {
            let header = &stripped[pos + 4..body];
            let (trait_name, type_name) = parse_impl_header(header);
            if type_name.is_empty() {
                continue;
            }
            let end = match_brace(flat, body);
            impls.push(ImplSpan {
                type_name,
                trait_name,
                start: line_of(&line_starts, pos),
                end: line_of(&line_starts, end),
            });
        }
    }

    SourceMap { lines, is_test, fns, impls }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_comments_and_strings_but_keeps_positions() {
        let src = "let a = 1; // HashMap in a comment\nlet s = \"thread_rng\"; let b = 2;\n";
        let out = strip(src);
        assert!(!out.contains("HashMap"));
        assert!(!out.contains("thread_rng"));
        // positions preserved: `let b = 2;` still at its column
        assert_eq!(out.len(), src.len());
        assert!(out.lines().nth(1).unwrap().contains("let b = 2;"));
    }

    #[test]
    fn raw_strings_nested_comments_chars_lifetimes() {
        let src = r###"let r = r#"HashMap "quoted" inside"#; /* outer /* HashMap */ still */ let c = '"'; fn f<'a>(x: &'a str) {}"###;
        let out = strip(src);
        assert!(!out.contains("HashMap"));
        assert!(out.contains("fn f<'a>"), "lifetimes must survive: {out}");
        assert!(out.contains("let c ="));
    }

    #[test]
    fn cfg_test_region_is_marked() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { m.unwrap(); }\n}\nfn live2() {}\n";
        let m = analyze_source(src);
        assert!(!m.line_is_test(1));
        assert!(m.line_is_test(2));
        assert!(m.line_is_test(4));
        assert!(!m.line_is_test(6));
    }

    #[test]
    fn fn_and_impl_spans_are_brace_accurate() {
        let src = "\
impl<'a> Decoder<'a> {
    pub fn u32(&mut self) -> u32 {
        0
    }
}
impl Transport for LocalEndpoint {
    fn id(&self) -> usize { 0 }
}
fn free_standing() {
    let x = 1;
}
";
        let m = analyze_source(src);
        let dec = m.impls.iter().find(|i| i.type_name == "Decoder").unwrap();
        assert_eq!((dec.start, dec.end), (1, 5));
        assert_eq!(dec.trait_name, None);
        let tr = m.impls.iter().find(|i| i.type_name == "LocalEndpoint").unwrap();
        assert_eq!(tr.trait_name.as_deref(), Some("Transport"));
        let f = m.fns.iter().find(|f| f.name == "free_standing").unwrap();
        assert_eq!((f.start, f.end), (9, 11));
        let u = m.fns.iter().find(|f| f.name == "u32").unwrap();
        assert_eq!((u.start, u.end), (2, 4));
    }

    #[test]
    fn trait_method_declarations_have_no_span() {
        let src = "trait T {\n    fn decl(&self) -> usize;\n    fn with_default(&self) -> usize { 1 }\n}\n";
        let m = analyze_source(src);
        assert!(m.fns.iter().all(|f| f.name != "decl"));
        assert!(m.fns.iter().any(|f| f.name == "with_default"));
    }

    #[test]
    fn multi_hash_raw_strings_blank_embedded_terminators() {
        // `"#` inside an r##"…"## literal is NOT a terminator; the
        // call-site extractor depends on the brace after it surviving.
        let src = "let r = r##\"end\"# not yet HashMap\"##; fn after() { x }\n";
        let out = strip(src);
        assert!(!out.contains("HashMap"), "{out}");
        assert!(!out.contains("not yet"), "{out}");
        assert!(out.contains("fn after() { x }"), "{out}");
        assert_eq!(out.len(), src.len());
        let m = analyze_source(src);
        assert!(m.fns.iter().any(|f| f.name == "after"));
    }

    #[test]
    fn byte_strings_and_raw_byte_strings_are_blanked() {
        let src = "let a = b\"HashMap\\\"still\"; let b = br#\"thread_rng \"q\" tail\"#; fn f() {}\n";
        let out = strip(src);
        assert!(!out.contains("HashMap"), "{out}");
        assert!(!out.contains("still"), "{out}");
        assert!(!out.contains("thread_rng"), "{out}");
        assert!(!out.contains("tail"), "{out}");
        assert_eq!(out.len(), src.len());
        assert!(analyze_source(src).fns.iter().any(|f| f.name == "f"));
    }

    #[test]
    fn escaped_char_literals_do_not_swallow_following_code() {
        // '\'' and b'\\' both end at their real closing quote; the
        // worst failure mode is treating the escape's quote as the
        // terminator and blanking real code after it.
        let src = "let q = '\\''; let s = b'\\\\'; let n = '\\n'; fn g() { HashMap }\n";
        let out = strip(src);
        assert!(out.contains("fn g() { HashMap }"), "{out}");
        assert_eq!(out.len(), src.len());
        let m = analyze_source(src);
        let g = m.fns.iter().find(|f| f.name == "g").expect("fn g survives char literals");
        assert_eq!((g.start, g.end), (1, 1));
    }

    #[test]
    fn fn_spans_inside_nested_impl_and_mod_blocks() {
        let src = "\
mod outer {
    pub mod inner {
        impl Wrapper {
            pub fn method(&self) -> usize {
                helper()
            }
        }
        pub fn helper() -> usize {
            0
        }
    }
}
";
        let m = analyze_source(src);
        let meth = m.fns.iter().find(|f| f.name == "method").unwrap();
        assert_eq!((meth.start, meth.end), (4, 6));
        let help = m.fns.iter().find(|f| f.name == "helper").unwrap();
        assert_eq!((help.start, help.end), (8, 10));
        let im = m.impls.iter().find(|i| i.type_name == "Wrapper").unwrap();
        assert_eq!((im.start, im.end), (3, 7));
        // the nested-fn case the call graph leans on: a fn inside a fn
        // gets its own (inner) span so line->fn attribution can pick
        // the innermost one.
        let src2 = "fn outer_fn() {\n    fn inner_fn() {\n        1;\n    }\n    inner_fn();\n}\n";
        let m2 = analyze_source(src2);
        let o = m2.fns.iter().find(|f| f.name == "outer_fn").unwrap();
        let i = m2.fns.iter().find(|f| f.name == "inner_fn").unwrap();
        assert_eq!((o.start, o.end), (1, 6));
        assert_eq!((i.start, i.end), (2, 4));
    }
}
