//! Experiment configuration: every knob of a Parrot run in one struct,
//! parseable from CLI args and from plain `key=value` config files.
//!
//! This is the "real config system" seam: the launcher (`main.rs`), the
//! examples and every `exp/*` harness all build a [`RunConfig`] and hand
//! it to the coordinator, so a simulation and a TCP deployment differ
//! only in the transport field (§3.2 zero-code-change migration).

use crate::aggregation::StalenessWeight;
use crate::cluster::{ClusterProfile, Topology};
use crate::compress::Codec;
use crate::coordinator::selection::Selection;
use crate::data::PartitionKind;
use crate::simulation::{AvailabilityModel, ChurnSpec, DynamicsSpec, StragglerSpec};
use crate::util::cli::Args;
use anyhow::{bail, Result};

/// Which simulation scheme drives the round (§2.2, Fig. 1-2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    /// Single-process: one device trains everything sequentially.
    SP,
    /// Real-world distributed: M devices, M_p active, rest idle.
    RwDist,
    /// Selected-deployment: M_p devices, one client each.
    SdDist,
    /// Flexible-assignment (FedScale/Flower): K devices, greedy
    /// pull-one-task-at-a-time, per-task communication.
    FaDist,
    /// Parrot: K devices, scheduled task sets, hierarchical aggregation.
    Parrot,
    /// Asynchronous buffered execution (FedBuff-style): no round
    /// barrier — a work-conserving dispatcher keeps every device fed
    /// and the server applies a staleness-weighted flush whenever
    /// `--buffer` client updates accumulate.  `--buffer 0` (default)
    /// means M_p, which with `--max-staleness 0` reproduces the
    /// synchronous Parrot timeline exactly.
    Async,
}

impl Scheme {
    pub fn parse(s: &str) -> Result<Scheme> {
        Ok(match s {
            "sp" => Scheme::SP,
            "rw" | "rw_dist" => Scheme::RwDist,
            "sd" | "sd_dist" => Scheme::SdDist,
            "fa" | "fa_dist" => Scheme::FaDist,
            "parrot" => Scheme::Parrot,
            "async" => Scheme::Async,
            _ => bail!("unknown scheme {s:?} (sp|rw|sd|fa|parrot|async)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scheme::SP => "SP",
            Scheme::RwDist => "RW Dist.",
            Scheme::SdDist => "SD Dist.",
            Scheme::FaDist => "FA Dist.",
            Scheme::Parrot => "Parrot",
            Scheme::Async => "Async",
        }
    }
}

/// Scheduler selection (§4.3-4.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    /// No workload model: uniform round-robin split (the warm-up branch
    /// of Alg. 3, also the "Parrot w/o scheduling" ablation).
    Uniform,
    /// Alg. 3 with linear-regression estimation over ALL history.
    Greedy,
    /// Alg. 3 with Time-Window estimation (window = τ rounds).
    TimeWindow(usize),
    /// Alg. 3 plus a state-affinity term: placing a client on a worker
    /// other than its state's owner adds `weight_pct`% of the predicted
    /// state-movement time to that placement's cost (the distributed
    /// state store's scheduling knob).  `window = 0` estimates over all
    /// history; `window = τ` composes with Time-Window estimation.
    StateAffinity { window: usize, weight_pct: u32 },
}

impl SchedulerKind {
    pub fn parse(s: &str) -> Result<SchedulerKind> {
        if s == "uniform" || s == "none" {
            return Ok(SchedulerKind::Uniform);
        }
        if s == "greedy" || s == "full" {
            return Ok(SchedulerKind::Greedy);
        }
        // `affinity:P`, `greedy+affinity:P`, `window:T+affinity:P`.
        if let Some((base, aff)) = s.split_once("+affinity:") {
            let weight_pct: u32 = aff
                .parse()
                .map_err(|_| anyhow::anyhow!("bad affinity weight {aff:?} (percent)"))?;
            let window = match SchedulerKind::parse(base)? {
                SchedulerKind::Greedy => 0,
                SchedulerKind::TimeWindow(t) => t,
                other => bail!("affinity composes with greedy|window:T, not {other:?}"),
            };
            return Ok(SchedulerKind::StateAffinity { window, weight_pct });
        }
        if let Some(p) = s.strip_prefix("affinity:") {
            return Ok(SchedulerKind::StateAffinity {
                window: 0,
                weight_pct: p
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad affinity weight {p:?} (percent)"))?,
            });
        }
        if let Some(t) = s.strip_prefix("window:") {
            return Ok(SchedulerKind::TimeWindow(t.parse()?));
        }
        bail!("unknown scheduler {s:?} (uniform|greedy|window:T|affinity:P|window:T+affinity:P)")
    }

    pub fn name(&self) -> String {
        match self {
            SchedulerKind::Uniform => "uniform".into(),
            SchedulerKind::Greedy => "greedy".into(),
            SchedulerKind::TimeWindow(t) => format!("window:{t}"),
            SchedulerKind::StateAffinity { window: 0, weight_pct } => {
                format!("affinity:{weight_pct}")
            }
            SchedulerKind::StateAffinity { window, weight_pct } => {
                format!("window:{window}+affinity:{weight_pct}")
            }
        }
    }
}

/// A full run description.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// FL algorithm name (fedavg|fedprox|fednova|scaffold|feddyn|mime).
    pub algorithm: String,
    /// Model family (mlp|cnn|tinylm).
    pub model: String,
    /// Total clients M.
    pub n_clients: usize,
    /// Concurrent (selected) clients per round M_p.
    pub clients_per_round: usize,
    /// Devices K.
    pub n_devices: usize,
    /// Communication rounds R.
    pub rounds: usize,
    /// Local epochs E.
    pub local_epochs: usize,
    pub lr: f32,
    /// FedProx μ / FedDyn α.
    pub mu: f32,
    pub partition: PartitionKind,
    /// Mean per-client dataset size.
    pub mean_client_size: usize,
    pub scheme: Scheme,
    pub scheduler: SchedulerKind,
    /// Warm-up rounds R_w before the fitted schedule kicks in.
    pub warmup_rounds: usize,
    pub cluster: ClusterProfile,
    pub seed: u64,
    /// Directory with the AOT artifacts.
    pub artifact_dir: String,
    /// Directory for client-state snapshots (state manager).
    pub state_dir: String,
    /// Consistent-hash shards for the distributed client-state store
    /// (0 = legacy local-only store; n ≥ 1 gives worker i ownership of
    /// shard i, clamped to ≤ devices).
    pub state_shards: usize,
    /// Dirty write-back caching in the state store (explicit flush at
    /// round boundaries) instead of write-through.
    pub state_writeback: bool,
    /// State-affinity scheduling weight in percent (0 = off); > 0
    /// upgrades the scheduler to [`SchedulerKind::StateAffinity`].
    pub state_affinity: u32,
    /// Per-worker state cache budget in MB.
    pub state_cache_mb: usize,
    /// Test batches evaluated by the server each eval.
    pub eval_batches: usize,
    /// Evaluate every this many rounds (0 = never).
    pub eval_every: usize,
    /// Client selection strategy (Alg. 1's "server selects").
    pub selection: Selection,
    /// Client availability, device churn, and straggler injection for
    /// the virtual-time engine (default: fully static).
    pub dynamics: DynamicsSpec,
    /// Update-compression codec negotiated for every round's uploads
    /// (`--compress none|fp16|qint8|topk:<frac>`).
    pub compress: Codec,
    /// Async scheme: client updates per buffered flush (`--buffer`;
    /// 0 = M_p, the sync-degenerate default).
    pub buffer: usize,
    /// Async scheme: updates staler than this many flushes are dropped
    /// (`--max-staleness`).
    pub max_staleness: usize,
    /// Async scheme: staleness discount law
    /// (`--staleness-weight const|poly:a`).
    pub staleness_weight: StalenessWeight,
    /// Engine worker threads for the group-sharded simulation path
    /// (`--threads`; ≥ 1).  Purely a wall-clock knob — the timeline is
    /// byte-identical for every value.
    pub threads: usize,
    /// Chrome trace-event export path (`--trace PATH`); None = tracing
    /// off (the no-op sink).  Sim exports carry virtual time and are
    /// byte-identical per seed for every `--threads`; deploy exports
    /// carry wallclock time through the same span taxonomy.
    pub trace: Option<String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            algorithm: "fedavg".into(),
            model: "mlp".into(),
            n_clients: 120,
            clients_per_round: 24,
            n_devices: 4,
            rounds: 10,
            local_epochs: 1,
            lr: 0.05,
            mu: 0.0,
            partition: PartitionKind::Natural,
            mean_client_size: 60,
            scheme: Scheme::Parrot,
            scheduler: SchedulerKind::Greedy,
            warmup_rounds: 2,
            cluster: ClusterProfile::homogeneous(4),
            seed: 42,
            artifact_dir: "artifacts".into(),
            state_dir: "state_cache".into(),
            state_shards: 0,
            state_writeback: false,
            state_affinity: 0,
            state_cache_mb: 64,
            eval_batches: 10,
            eval_every: 1,
            selection: Selection::Random,
            dynamics: DynamicsSpec::default(),
            compress: Codec::None,
            buffer: 0,
            max_staleness: 0,
            staleness_weight: StalenessWeight::Const,
            threads: 1,
            trace: None,
        }
    }
}

impl RunConfig {
    /// Load a plain `key=value` config file ('#' comments, blank lines
    /// ok; keys are the CLI flag names).  CLI args overlay the file, so
    /// `parrot run --config exp.cfg --devices 8` works as expected.
    pub fn from_file(path: &str) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading config {path}: {e}"))?;
        let mut argv = Vec::new();
        for (lno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("{path}:{}: expected key=value", lno + 1))?;
            argv.push(format!("--{}={}", k.trim(), v.trim()));
        }
        RunConfig::default().apply_args(&Args::parse(argv)?)
    }

    /// Overlay CLI args onto this config (every field addressable).
    pub fn apply_args(mut self, a: &Args) -> Result<RunConfig> {
        self.algorithm = a.get_or("algorithm", &self.algorithm).to_string();
        self.model = a.get_or("model", &self.model).to_string();
        self.n_clients = a.usize_or("clients", self.n_clients)?;
        self.clients_per_round = a.usize_or("per-round", self.clients_per_round)?;
        self.n_devices = a.usize_or("devices", self.n_devices)?;
        self.rounds = a.usize_or("rounds", self.rounds)?;
        self.local_epochs = a.usize_or("epochs", self.local_epochs)?;
        self.lr = a.f64_or("lr", self.lr as f64)? as f32;
        self.mu = a.f64_or("mu", self.mu as f64)? as f32;
        if let Some(p) = a.get("partition") {
            self.partition = PartitionKind::parse(p)?;
        }
        self.mean_client_size = a.usize_or("mean-size", self.mean_client_size)?;
        if let Some(s) = a.get("scheme") {
            self.scheme = Scheme::parse(s)?;
        }
        if let Some(s) = a.get("scheduler") {
            self.scheduler = SchedulerKind::parse(s)?;
        }
        self.warmup_rounds = a.usize_or("warmup", self.warmup_rounds)?;
        // Rebuilding the cluster (profile switch or device-count change)
        // must not silently drop a topology set earlier (config file →
        // CLI overlay ordering).
        if let Some(c) = a.get("cluster") {
            let topo = self.cluster.topology.clone();
            self.cluster = ClusterProfile::parse(c, self.n_devices)?.with_topology(topo);
        } else if self.cluster.n_devices() != self.n_devices {
            let topo = self.cluster.topology.clone();
            self.cluster =
                ClusterProfile::homogeneous(self.n_devices).with_topology(topo);
        }
        if let Some(t) = a.get("topology") {
            self.cluster.topology = Topology::parse(t)?;
        }
        self.seed = a.u64_or("seed", self.seed)?;
        self.artifact_dir = a.get_or("artifacts", &self.artifact_dir).to_string();
        self.state_dir = a.get_or("state-dir", &self.state_dir).to_string();
        self.state_shards = a.usize_or("state-shards", self.state_shards)?;
        self.state_writeback = match a.get("state-writeback") {
            Some(v) => match v {
                "true" | "1" | "on" => true,
                "false" | "0" | "off" => false,
                _ => bail!("--state-writeback: expected on|off, got {v:?}"),
            },
            None => self.state_writeback || a.flag("state-writeback"),
        };
        self.state_affinity = a.usize_or("state-affinity", self.state_affinity as usize)? as u32;
        self.state_cache_mb = a.usize_or("state-cache-mb", self.state_cache_mb)?;
        if self.state_affinity > 0 {
            // The affinity weight is a SchedulerKind-level knob: it
            // upgrades model-based kinds in place (Uniform stays
            // uniform — there is no placement objective to bias).
            self.scheduler = match self.scheduler {
                SchedulerKind::Greedy => SchedulerKind::StateAffinity {
                    window: 0,
                    weight_pct: self.state_affinity,
                },
                SchedulerKind::TimeWindow(t) => SchedulerKind::StateAffinity {
                    window: t,
                    weight_pct: self.state_affinity,
                },
                SchedulerKind::StateAffinity { window, .. } => SchedulerKind::StateAffinity {
                    window,
                    weight_pct: self.state_affinity,
                },
                SchedulerKind::Uniform => SchedulerKind::Uniform,
            };
        }
        self.eval_batches = a.usize_or("eval-batches", self.eval_batches)?;
        self.eval_every = a.usize_or("eval-every", self.eval_every)?;
        if let Some(sel) = a.get("selection") {
            self.selection = Selection::parse(sel)?;
        }
        if let Some(av) = a.get("availability") {
            self.dynamics.availability = AvailabilityModel::parse(av)?;
        }
        if let Some(ch) = a.get("churn") {
            self.dynamics.churn = ChurnSpec::parse(ch)?;
        }
        if let Some(st) = a.get("stragglers") {
            let drop_prob = self.dynamics.straggler.drop_prob;
            self.dynamics.straggler = StragglerSpec::parse(st)?;
            self.dynamics.straggler.drop_prob = drop_prob;
        }
        self.dynamics.straggler.drop_prob =
            a.f64_or("drop-prob", self.dynamics.straggler.drop_prob)?;
        if let Some(c) = a.get("compress") {
            self.compress = Codec::parse(c)?;
        }
        self.buffer = a.usize_or("buffer", self.buffer)?;
        self.max_staleness = a.usize_or("max-staleness", self.max_staleness)?;
        if let Some(w) = a.get("staleness-weight") {
            self.staleness_weight = StalenessWeight::parse(w)?;
        }
        self.threads = a.usize_or("threads", self.threads)?;
        if let Some(path) = a.get("trace") {
            self.trace = Some(path.to_string());
        }
        self.validate()?;
        Ok(self)
    }

    pub fn validate(&self) -> Result<()> {
        if self.clients_per_round > self.n_clients {
            bail!(
                "per-round {} > clients {}",
                self.clients_per_round,
                self.n_clients
            );
        }
        if self.n_devices == 0 || self.clients_per_round == 0 || self.n_clients == 0 {
            bail!("clients/per-round/devices must be positive");
        }
        if !crate::model::MODEL_NAMES.contains(&self.model.as_str()) {
            bail!("unknown model {:?}", self.model);
        }
        if self.cluster.n_devices() != self.n_devices {
            bail!(
                "cluster profile has {} devices, config wants {}",
                self.cluster.n_devices(),
                self.n_devices
            );
        }
        let topo = &self.cluster.topology;
        topo.validate(self.n_devices)?;
        if !topo.is_flat() {
            if !matches!(self.scheme, Scheme::Parrot | Scheme::Async) {
                bail!(
                    "--topology {} requires hierarchical aggregation \
                     (--scheme parrot|async); {:?} has no aggregator tier",
                    topo.name(),
                    self.scheme
                );
            }
            if self.scheme == Scheme::Async && topo.depth() > 1 {
                bail!(
                    "--scheme async prices one aggregator tier: use --topology \
                     groups:G, not {}",
                    topo.name()
                );
            }
        }
        if self.state_shards > self.n_devices {
            bail!(
                "--state-shards {} > devices {} (shard i is hosted by worker i)",
                self.state_shards,
                self.n_devices
            );
        }
        if self.state_affinity > 1000 {
            bail!("--state-affinity {}% is absurd (max 1000)", self.state_affinity);
        }
        if self.state_shards > 0 && self.scheme == Scheme::FaDist {
            bail!(
                "--state-shards needs a planned scheme (parrot|sp|async): FA's pull model \
                 has no round plan to prefetch state against"
            );
        }
        if self.scheme == Scheme::Async {
            if self.buffer > self.clients_per_round {
                bail!(
                    "--buffer {} > per-round {}: a flush could never fill",
                    self.buffer,
                    self.clients_per_round
                );
            }
            let has_churn = !self.dynamics.churn.events.is_empty()
                || self.dynamics.churn.leave_prob > 0.0
                || self.dynamics.churn.join_prob > 0.0;
            if has_churn {
                bail!(
                    "--scheme async does not model device churn (availability and \
                     straggler slowdowns are supported); drop --churn"
                );
            }
            if self.dynamics.straggler.drop_prob > 0.0 {
                // A mid-task drop removes an update from the stream, so
                // the buffer no longer fills at cohort boundaries and
                // the documented `buffer == M_p` sync-degenerate pin
                // would silently break; reject rather than diverge.
                bail!(
                    "--scheme async does not model mid-task client drops; \
                     drop --drop-prob (straggler slowdowns are supported)"
                );
            }
        } else if self.buffer > 0
            || self.max_staleness > 0
            || self.staleness_weight != StalenessWeight::Const
        {
            bail!(
                "--buffer/--max-staleness/--staleness-weight only apply to --scheme async"
            );
        }
        if self.threads == 0 {
            bail!("--threads must be >= 1 (1 = the single-worker sharded engine)");
        }
        self.dynamics.validate()?;
        Ok(())
    }

    /// The artifact base name for a step kind, e.g. "mlp_train".
    pub fn artifact(&self, kind: &str) -> String {
        format!("{}_{}", self.model, kind)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn defaults_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn cli_overlay() {
        let c = RunConfig::default()
            .apply_args(&args(&[
                "--clients", "1000", "--per-round", "100", "--devices", "8",
                "--scheme", "fa", "--scheduler", "window:5",
                "--partition", "dirichlet:0.1",
            ]))
            .unwrap();
        assert_eq!(c.n_clients, 1000);
        assert_eq!(c.scheme, Scheme::FaDist);
        assert_eq!(c.scheduler, SchedulerKind::TimeWindow(5));
        assert_eq!(c.partition, PartitionKind::Dirichlet(0.1));
        assert_eq!(c.cluster.n_devices(), 8);
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(RunConfig::default()
            .apply_args(&args(&["--per-round", "500"]))
            .is_err());
        assert!(RunConfig::default()
            .apply_args(&args(&["--model", "resnet999"]))
            .is_err());
        assert!(RunConfig::default()
            .apply_args(&args(&["--scheme", "wat"]))
            .is_err());
    }

    #[test]
    fn dynamics_flags_parse_and_validate() {
        let c = RunConfig::default()
            .apply_args(&args(&[
                "--availability", "0.8",
                "--churn", "leave@2:1:5.0,join@5:1",
                "--stragglers", "0.1:x4",
                "--drop-prob", "0.02",
            ]))
            .unwrap();
        assert!(!c.dynamics.is_static());
        assert!(matches!(
            c.dynamics.availability,
            AvailabilityModel::Bernoulli(p) if (p - 0.8).abs() < 1e-12
        ));
        assert_eq!(c.dynamics.churn.events.len(), 2);
        assert!((c.dynamics.straggler.prob - 0.1).abs() < 1e-12);
        assert!((c.dynamics.straggler.drop_prob - 0.02).abs() < 1e-12);
        // defaults stay fully static
        assert!(RunConfig::default().dynamics.is_static());
        // bad specs rejected
        assert!(RunConfig::default().apply_args(&args(&["--availability", "1.8"])).is_err());
        assert!(RunConfig::default().apply_args(&args(&["--churn", "explode@1:2"])).is_err());
        assert!(RunConfig::default().apply_args(&args(&["--drop-prob", "7"])).is_err());
    }

    #[test]
    fn compress_flag_parses_and_validates() {
        assert_eq!(RunConfig::default().compress, Codec::None);
        let c = RunConfig::default()
            .apply_args(&args(&["--compress", "qint8"]))
            .unwrap();
        assert_eq!(c.compress, Codec::QInt8);
        let t = RunConfig::default()
            .apply_args(&args(&["--compress", "topk:0.1"]))
            .unwrap();
        assert!(matches!(t.compress, Codec::TopK(f) if (f - 0.1).abs() < 1e-12));
        assert!(RunConfig::default().apply_args(&args(&["--compress", "topk:0"])).is_err());
        assert!(RunConfig::default().apply_args(&args(&["--compress", "gzip"])).is_err());
    }

    #[test]
    fn async_flags_parse_and_validate() {
        let c = RunConfig::default()
            .apply_args(&args(&[
                "--scheme", "async", "--buffer", "8", "--max-staleness", "3",
                "--staleness-weight", "poly:0.5",
            ]))
            .unwrap();
        assert_eq!(c.scheme, Scheme::Async);
        assert_eq!(c.buffer, 8);
        assert_eq!(c.max_staleness, 3);
        assert!(matches!(c.staleness_weight, StalenessWeight::Poly(a) if (a - 0.5).abs() < 1e-12));
        // Defaults are the sync-degenerate configuration.
        let d = RunConfig::default();
        assert_eq!((d.buffer, d.max_staleness, d.staleness_weight), (0, 0, StalenessWeight::Const));
        // A buffer no cohort stream could ever fill is rejected.
        assert!(RunConfig::default()
            .apply_args(&args(&["--scheme", "async", "--buffer", "999"]))
            .is_err());
        // Async knobs without the async scheme are a config error — the
        // staleness law included (it would otherwise be silently inert).
        assert!(RunConfig::default().apply_args(&args(&["--buffer", "4"])).is_err());
        assert!(RunConfig::default().apply_args(&args(&["--max-staleness", "2"])).is_err());
        assert!(RunConfig::default()
            .apply_args(&args(&["--staleness-weight", "poly:0.5"]))
            .is_err());
        // Device churn and mid-task drops are not modeled by the async
        // dispatcher (a drop would break the buffer == M_p sync pin).
        assert!(RunConfig::default()
            .apply_args(&args(&["--scheme", "async", "--churn", "leave@2:1:5.0"]))
            .is_err());
        assert!(RunConfig::default()
            .apply_args(&args(&["--scheme", "async", "--drop-prob", "0.05"]))
            .is_err());
        // ...but availability and straggler slowdowns are fine.
        assert!(RunConfig::default()
            .apply_args(&args(&["--scheme", "async", "--availability", "0.8",
                "--stragglers", "0.1:x4"]))
            .is_ok());
        // Bad staleness law rejected.
        assert!(RunConfig::default()
            .apply_args(&args(&["--scheme", "async", "--staleness-weight", "exp:2"]))
            .is_err());
        // The async scheme may drive the sharded state store.
        assert!(RunConfig::default()
            .apply_args(&args(&["--scheme", "async", "--state-shards", "2"]))
            .is_ok());
    }

    #[test]
    fn topology_flag_parses_and_validates() {
        // Default: flat, byte-identical to the pre-topology engine.
        assert!(RunConfig::default().cluster.topology.is_flat());
        let c = RunConfig::default()
            .apply_args(&args(&["--topology", "groups:2"]))
            .unwrap();
        assert_eq!(c.cluster.topology.n_groups(), 2);
        // Survives a cluster rebuild from a later device-count overlay.
        let c2 = c.apply_args(&args(&["--devices", "8"])).unwrap();
        assert_eq!(c2.cluster.topology.n_groups(), 2);
        assert_eq!(c2.cluster.n_devices(), 8);
        // ... and a profile switch.
        let c3 = c2.apply_args(&args(&["--cluster", "hete"])).unwrap();
        assert_eq!(c3.cluster.topology.n_groups(), 2);
        // Trees parse; deeper-than-one rejected for async only.
        let t = RunConfig::default()
            .apply_args(&args(&["--devices", "8", "--per-round", "24", "--topology", "tree:2x2"]))
            .unwrap();
        assert_eq!(t.cluster.topology.depth(), 2);
        assert!(RunConfig::default()
            .apply_args(&args(&[
                "--devices", "8", "--per-round", "24", "--scheme", "async",
                "--topology", "tree:2x2",
            ]))
            .is_err());
        assert!(RunConfig::default()
            .apply_args(&args(&["--scheme", "async", "--topology", "groups:2"]))
            .is_ok());
        // More groups than devices is a config error.
        assert!(RunConfig::default()
            .apply_args(&args(&["--topology", "groups:99"]))
            .is_err());
        // Schemes without an aggregator tier reject grouping.
        for scheme in ["fa", "sd", "rw", "sp"] {
            assert!(
                RunConfig::default()
                    .apply_args(&args(&["--scheme", scheme, "--topology", "groups:2"]))
                    .is_err(),
                "{scheme}"
            );
        }
        // Bad specs rejected.
        assert!(RunConfig::default()
            .apply_args(&args(&["--topology", "rings:2"]))
            .is_err());
    }

    #[test]
    fn scheme_and_scheduler_parsing() {
        assert_eq!(Scheme::parse("parrot").unwrap(), Scheme::Parrot);
        assert_eq!(Scheme::parse("async").unwrap(), Scheme::Async);
        assert_eq!(Scheme::parse("sd_dist").unwrap(), Scheme::SdDist);
        assert_eq!(SchedulerKind::parse("uniform").unwrap(), SchedulerKind::Uniform);
        assert!(SchedulerKind::parse("window:x").is_err());
    }

    #[test]
    fn affinity_scheduler_parses_and_round_trips() {
        for s in ["affinity:50", "window:5+affinity:100", "greedy+affinity:25"] {
            let k = SchedulerKind::parse(s).unwrap();
            assert!(matches!(k, SchedulerKind::StateAffinity { .. }), "{s}");
            assert_eq!(SchedulerKind::parse(&k.name()).unwrap(), k, "{s} round trip");
        }
        assert_eq!(
            SchedulerKind::parse("window:3+affinity:40").unwrap(),
            SchedulerKind::StateAffinity { window: 3, weight_pct: 40 }
        );
        assert!(SchedulerKind::parse("affinity:x").is_err());
        assert!(SchedulerKind::parse("uniform+affinity:10").is_err());
    }

    #[test]
    fn state_store_flags_parse_validate_and_upgrade_scheduler() {
        let c = RunConfig::default()
            .apply_args(&args(&[
                "--state-shards", "4", "--state-writeback",
                "--state-affinity", "80", "--state-cache-mb", "16",
            ]))
            .unwrap();
        assert_eq!(c.state_shards, 4);
        assert!(c.state_writeback);
        assert_eq!(c.state_cache_mb, 16);
        assert_eq!(c.scheduler, SchedulerKind::StateAffinity { window: 0, weight_pct: 80 });
        // Affinity composes with an existing time window.
        let w = RunConfig::default()
            .apply_args(&args(&["--scheduler", "window:5", "--state-affinity", "30"]))
            .unwrap();
        assert_eq!(w.scheduler, SchedulerKind::StateAffinity { window: 5, weight_pct: 30 });
        // Uniform stays uniform — nothing to bias.
        let u = RunConfig::default()
            .apply_args(&args(&["--scheduler", "uniform", "--state-affinity", "30"]))
            .unwrap();
        assert_eq!(u.scheduler, SchedulerKind::Uniform);
        // Explicit off-switch for writeback.
        let off = RunConfig::default()
            .apply_args(&args(&["--state-writeback", "off"]))
            .unwrap();
        assert!(!off.state_writeback);
        // Defaults are the legacy local-only store.
        let d = RunConfig::default();
        assert_eq!((d.state_shards, d.state_writeback, d.state_affinity), (0, false, 0));
        // More shards than devices is a config error.
        assert!(RunConfig::default().apply_args(&args(&["--state-shards", "99"])).is_err());
        assert!(RunConfig::default()
            .apply_args(&args(&["--state-writeback", "banana"]))
            .is_err());
        // FA has no round plan to prefetch against.
        assert!(RunConfig::default()
            .apply_args(&args(&["--scheme", "fa", "--state-shards", "2"]))
            .is_err());
    }
}

#[cfg(test)]
mod file_tests {
    use super::*;

    fn write_cfg(name: &str, body: &str) -> String {
        let p = std::env::temp_dir().join(format!("parrot_cfg_{}_{name}", std::process::id()));
        std::fs::write(&p, body).unwrap();
        p.to_string_lossy().into_owned()
    }

    #[test]
    fn config_file_parses_with_comments() {
        let p = write_cfg(
            "basic",
            "# paper-scale run\nclients = 1000\nper-round=100\ndevices = 8\n\
             scheduler = window:5  # dynamic env\npartition = dirichlet:0.1\n",
        );
        let c = RunConfig::from_file(&p).unwrap();
        assert_eq!(c.n_clients, 1000);
        assert_eq!(c.clients_per_round, 100);
        assert_eq!(c.scheduler, SchedulerKind::TimeWindow(5));
        assert_eq!(c.partition, crate::data::PartitionKind::Dirichlet(0.1));
    }

    #[test]
    fn cli_overlays_file() {
        let p = write_cfg("overlay", "clients=500\nper-round=50\ndevices=4\n");
        let cfg = RunConfig::from_file(&p).unwrap();
        let a = Args::parse(["--devices".to_string(), "16".to_string()]).unwrap();
        let c = cfg.apply_args(&a).unwrap();
        assert_eq!(c.n_clients, 500);
        assert_eq!(c.n_devices, 16);
        assert_eq!(c.cluster.n_devices(), 16);
    }

    #[test]
    fn bad_file_rejected() {
        assert!(RunConfig::from_file("/nonexistent/x.cfg").is_err());
        let p = write_cfg("bad", "this is not kv\n");
        assert!(RunConfig::from_file(&p).is_err());
        let p2 = write_cfg("badval", "clients=banana\n");
        assert!(RunConfig::from_file(&p2).is_err());
    }
}
