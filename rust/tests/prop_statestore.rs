//! Property suite for the distributed client-state store: consistent-
//! hash ownership stability, and loss-free shard handoff under churn
//! (differential against a single-shard store on identical sequences).
//! Replay any failure with `PARROT_PROP_SEED=<u64>` (scripts/ci.sh adds
//! a random-seed pass).

use parrot::statestore::{ShardMap, SimStore, SimStoreCfg};
use parrot::util::prop::{check, Gen};

/// Adding or removing ONE shard remaps only the clients adjacent to
/// that shard's ring points: strictly no third-party movement, and the
/// moved set stays ≈ M/n (⌈M/n⌉ plus concentration slack — 128 vnodes
/// put shard loads within a few σ of the mean).
#[test]
fn prop_consistent_hash_minimal_remap() {
    check("consistent-hash minimal remap", 40, |g| {
        let n = g.int(2, 24);
        let m = 200 + g.int(0, 1800);
        let before = ShardMap::new(n);
        let slack = m.div_ceil(2 * n) + 24;
        let bound = m.div_ceil(n) + slack;

        // Removal: only the removed shard's clients move.
        let victim = g.int(0, n - 1) as u32;
        let mut after = before.clone();
        if !after.remove_shard(victim) {
            return Err(format!("shard {victim} of {n} must be removable"));
        }
        let mut moved = 0usize;
        for c in 0..m as u64 {
            let (o0, o1) = (before.owner(c), after.owner(c));
            if o0 == victim {
                moved += 1;
                if o1 == victim {
                    return Err(format!("client {c} still mapped to removed shard"));
                }
            } else if o0 != o1 {
                return Err(format!(
                    "client {c} moved {o0}→{o1} though shard {victim} was removed"
                ));
            }
        }
        if moved > bound {
            return Err(format!(
                "removal remapped {moved} of {m} clients, bound ⌈M/n⌉+slack = {bound} (n={n})"
            ));
        }

        // Addition: every moved client moves TO the new shard.
        let newbie = n as u32;
        let mut grown = before.clone();
        if !grown.add_shard(newbie) {
            return Err("fresh shard id must be addable".into());
        }
        let add_bound = m.div_ceil(n + 1) + slack;
        let mut pulled = 0usize;
        for c in 0..m as u64 {
            let (o0, o1) = (before.owner(c), grown.owner(c));
            if o0 != o1 {
                pulled += 1;
                if o1 != newbie {
                    return Err(format!("client {c} remapped {o0}→{o1}, not to the new shard"));
                }
            }
        }
        if pulled > add_bound {
            return Err(format!(
                "addition remapped {pulled} of {m} clients, bound {add_bound} (n={n})"
            ));
        }
        Ok(())
    });
}

/// Drive a sharded store and a single-shard reference store through the
/// SAME training sequence, with random device departures/rejoins (and
/// their shard handoffs) hitting only the sharded one: after every
/// round both must agree on exactly which clients have state and at
/// which version — a handoff that loses or regresses a state breaks
/// the differential immediately.
#[test]
fn prop_shard_handoff_loses_no_state() {
    check("shard handoff differential", 25, |g| {
        let k = g.int(2, 6);
        let m = 30 + g.int(0, 90);
        let s_d = 512u64;
        let budget = (1 + g.int(0, 6)) * s_d as usize; // tight → evictions + spills
        let mut sharded = SimStore::new(SimStoreCfg::new(k, k, s_d, budget).write_back(true));
        // Reference: one shard on one worker, same budget per worker.
        let mut single = SimStore::new(SimStoreCfg::new(1, 1, s_d, budget).write_back(true));
        let mut dead: Vec<usize> = Vec::new();
        let rounds = 3 + g.int(0, 5);
        for round in 0..rounds as u64 {
            // One plan: distinct clients split over the K workers (the
            // reference runs them all on its only worker, same order).
            let mut lists: Vec<Vec<u64>> = vec![Vec::new(); k];
            let mut flat: Vec<u64> = Vec::new();
            let n_tasks = g.int(1, 3 * k);
            let mut used = std::collections::BTreeSet::new();
            for i in 0..n_tasks {
                let c = g.int(0, m - 1) as u64;
                if used.insert(c) {
                    lists[i % k].push(c);
                    flat.push(c);
                }
            }
            sharded.plan_round(round, &lists);
            single.plan_round(round, &[flat]);

            // Random churn on the sharded store only.
            if g.bool() {
                let w = g.int(0, k - 1);
                if !dead.contains(&w) {
                    sharded.handoff(w);
                    dead.push(w);
                }
            }
            if g.bool() {
                if let Some(w) = dead.pop() {
                    sharded.rejoin(w);
                }
            }

            // The differential: identical live state, every round.
            let (a, b) = (sharded.snapshot(), single.snapshot());
            if a != b {
                return Err(format!(
                    "round {round}: sharded live state {:?} != reference {:?} (dead={dead:?})",
                    a, b
                ));
            }
            // And no copy may be stranded at a worker that lost (or
            // never had) ownership — handoff/rejoin must relocate
            // cached state along with the ring.
            let stranded = sharded.misplaced_cache_entries();
            if stranded != 0 {
                return Err(format!(
                    "round {round}: {stranded} cache entries off-owner (dead={dead:?})"
                ));
            }
        }
        // Every remote move and every handoff is exactly two network
        // legs of s_d through the server — the byte counters must be
        // exact multiples, not approximations.
        let m1 = sharded.metrics;
        if m1.remote_bytes % (2 * s_d) != 0 {
            return Err(format!("remote bytes {} not a 2·s_d multiple", m1.remote_bytes));
        }
        if m1.remote_bytes != 2 * s_d * (m1.remote_fetches + m1.remote_returns) {
            return Err("remote bytes must equal 2·s_d per fetch/return".into());
        }
        if m1.shard_transfer_bytes != 2 * s_d * m1.shard_transfers {
            return Err("transfer bytes must equal 2·s_d per moved state".into());
        }
        Ok(())
    });
}

/// The prefetch ready-times are a per-worker pipeline: monotone in task
/// order and exactly the running sum of load stalls.
#[test]
fn prop_prefetch_channel_is_cumulative() {
    check("prefetch channel", 30, |g| {
        let k = 1 + g.int(0, 3);
        let m = 20 + g.int(0, 40);
        let mut store =
            SimStore::new(SimStoreCfg::new(k, k, 1024, 2 * 1024).write_back(true));
        for round in 0..3u64 {
            let mut lists: Vec<Vec<u64>> = vec![Vec::new(); k];
            for i in 0..g.int(0, 12) {
                lists[i % k].push(g.int(0, m - 1) as u64);
            }
            // A client must appear at most once per round.
            for l in &mut lists {
                l.sort_unstable();
                l.dedup();
            }
            let all: std::collections::BTreeSet<u64> =
                lists.iter().flatten().copied().collect();
            if all.len() != lists.iter().map(|l| l.len()).sum::<usize>() {
                // Cross-worker duplicate drawn: drop the round.
                continue;
            }
            let (legs, _, _) = store.plan_round(round, &lists);
            for worker in legs {
                let mut chan = 0.0f64;
                for leg in worker {
                    chan += leg.secs;
                    if (leg.ready - chan).abs() > 1e-9 {
                        return Err(format!(
                            "ready {} != cumulative stall {chan}",
                            leg.ready
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}
