//! End-to-end coordinator tests on real compute: full FL rounds through
//! server + worker threads + PJRT, for every algorithm and both wire
//! modes.  Skips cleanly when artifacts are absent.

use parrot::config::{RunConfig, Scheme, SchedulerKind};
use parrot::coordinator::run_simulation;
use std::path::Path;

fn artifacts_ready() -> bool {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/mlp_train.hlo.txt")
        .exists()
}

fn base_cfg(tag: u64) -> RunConfig {
    RunConfig {
        n_clients: 12,
        clients_per_round: 4,
        n_devices: 2,
        rounds: 3,
        local_epochs: 1,
        mean_client_size: 30,
        warmup_rounds: 1,
        eval_every: 3,
        eval_batches: 4,
        seed: 1000 + tag,
        artifact_dir: Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts")
            .to_string_lossy()
            .into_owned(),
        state_dir: std::env::temp_dir()
            .join(format!("parrot_it_{}_{tag}", std::process::id()))
            .to_string_lossy()
            .into_owned(),
        cluster: parrot::cluster::ClusterProfile::homogeneous(2),
        ..Default::default()
    }
}

#[test]
fn fedavg_parrot_round_trip_improves_loss() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut cfg = base_cfg(1);
    cfg.rounds = 6;
    cfg.eval_every = 2;
    let summary = run_simulation(cfg).unwrap();
    assert_eq!(summary.metrics.rounds.len(), 6);
    // Loss must drop from init (≈ln 62 ≈ 4.13) over 6 rounds on the
    // easy synthetic task.
    let acc = summary.final_acc.expect("eval ran");
    let loss = summary.final_loss.unwrap();
    assert!(loss < 4.0, "final eval loss {loss}");
    assert!(acc > 1.0 / 62.0, "must beat chance, acc={acc}");
    // Comm accounting sane: O(K) trips per round = 2 per active device.
    for r in &summary.metrics.rounds {
        assert!(r.trips <= 2 * 2, "parrot trips {} > 2K", r.trips);
        assert!(r.bytes_up > 0 && r.bytes_down > 0);
        assert!(r.wall_secs > 0.0);
    }
}

#[test]
fn all_algorithms_run_and_learn() {
    if !artifacts_ready() {
        return;
    }
    for (i, algo) in ["fedprox", "fednova", "scaffold", "feddyn", "mime"]
        .iter()
        .enumerate()
    {
        let mut cfg = base_cfg(10 + i as u64);
        cfg.algorithm = algo.to_string();
        cfg.mu = 0.01;
        let summary =
            run_simulation(cfg).unwrap_or_else(|e| panic!("{algo} failed: {e:#}"));
        let loss = summary.final_loss.unwrap();
        assert!(
            loss.is_finite() && loss < 4.2,
            "{algo}: implausible final loss {loss}"
        );
    }
}

#[test]
fn async_scheme_streams_flushes_and_learns() {
    if !artifacts_ready() {
        return;
    }
    // The streaming async loop end-to-end on real compute: R x M_p
    // tasks flow through AsyncTask/TaskDone with staleness-weighted
    // flushes every `buffer` updates; one RoundMetrics per flush.
    let mut cfg = base_cfg(80);
    cfg.scheme = parrot::config::Scheme::Async;
    cfg.rounds = 4;
    cfg.clients_per_round = 6;
    cfg.buffer = 3;
    cfg.max_staleness = 2;
    cfg.staleness_weight = parrot::aggregation::StalenessWeight::Poly(0.5);
    cfg.eval_every = 8; // one eval on the final flush
    let summary = run_simulation(cfg).unwrap();
    // 24 updates / buffer 3 = 8 flushes (plus maybe an empty-partial none).
    assert_eq!(summary.metrics.rounds.len(), 8, "one RoundMetrics per flush");
    let applied: usize = summary.metrics.rounds.iter().map(|r| r.flush_updates).sum();
    let stale: usize = summary.metrics.rounds.iter().map(|r| r.stale_dropped).sum();
    assert_eq!(applied + stale, 24, "every update flushed exactly once");
    for r in &summary.metrics.rounds {
        assert!(r.bytes_up > 0 && r.bytes_down > 0);
        assert!(r.wall_secs > 0.0);
    }
    let loss = summary.final_loss.expect("eval ran");
    assert!(loss.is_finite() && loss < 4.2, "implausible final loss {loss}");
}

#[test]
fn async_sharded_state_prefetch_round_trips() {
    if !artifacts_ready() {
        return;
    }
    // Async + sharded state: the rolling-horizon prefetch (StateFetch ->
    // StatePut forward -> deferred AsyncTask) and the write-back return
    // path must move state through the coordinator without losing any.
    let mut cfg = base_cfg(81);
    cfg.algorithm = "scaffold".into();
    cfg.scheme = parrot::config::Scheme::Async;
    cfg.rounds = 3;
    cfg.clients_per_round = 8;
    cfg.buffer = 4;
    cfg.max_staleness = 1;
    cfg.state_shards = 2;
    cfg.state_writeback = true;
    cfg.eval_every = 0;
    let summary = run_simulation(cfg).unwrap();
    let state_bytes: u64 = summary.metrics.rounds.iter().map(|r| r.state_bytes).sum();
    assert!(state_bytes > 0, "off-owner tasks must move state through the server");
}

#[test]
fn stateful_algorithms_persist_state() {
    if !artifacts_ready() {
        return;
    }
    let mut cfg = base_cfg(30);
    cfg.algorithm = "scaffold".into();
    cfg.rounds = 4;
    // Select every client every round so states certainly exist.
    cfg.clients_per_round = 12;
    let state_dir = cfg.state_dir.clone();
    let seed = cfg.seed;
    run_simulation(cfg).unwrap();
    let run_dir = Path::new(&state_dir).join(format!("run_{seed}"));
    let n_states = std::fs::read_dir(run_dir)
        .map(|d| {
            d.filter(|e| {
                e.as_ref()
                    .map(|e| e.file_name().to_string_lossy().ends_with(".state"))
                    .unwrap_or(false)
            })
            .count()
        })
        .unwrap_or(0);
    assert_eq!(n_states, 12, "every client must have persisted SCAFFOLD state");
}

#[test]
fn sharded_state_store_matches_local_and_spreads_state() {
    if !artifacts_ready() {
        return;
    }
    // Same run twice: legacy local state vs the sharded store with
    // plan-driven prefetch + write-back returns.  SCAFFOLD's numerics
    // must be identical (state content is exact either way), the
    // sharded run must move state through the coordinator, and every
    // state file must land in its owner's shard directory.
    let mk = |tag: u64, shards: usize| {
        let mut cfg = base_cfg(tag);
        cfg.algorithm = "scaffold".into();
        cfg.rounds = 4;
        cfg.clients_per_round = 12;
        cfg.state_shards = shards;
        cfg.state_writeback = shards > 0;
        cfg
    };
    let local = run_simulation(mk(70, 0)).unwrap();
    let sharded_cfg = mk(70, 2);
    let state_dir = sharded_cfg.state_dir.clone();
    let seed = sharded_cfg.seed;
    let sharded = run_simulation(sharded_cfg).unwrap();
    // Scheduling history is wallclock-fed, so placement (and thus the
    // float summation order) may differ run to run; exact math is
    // permutation-invariant, allow the usual small slack.
    let d = local.final_params.max_abs_diff(&sharded.final_params);
    assert!(d < 1e-4, "sharded state store changed the numerics: {d}");
    assert!(
        sharded.metrics.total_state_bytes() > 0,
        "off-owner clients must move state through the coordinator"
    );
    assert_eq!(local.metrics.total_state_bytes(), 0);
    // Ownership on disk: every state file sits in its owner's shard.
    let map = parrot::statestore::ShardMap::new(2);
    let run_dir = Path::new(&state_dir).join(format!("run_{seed}"));
    let mut found = 0usize;
    for w in 0..2usize {
        let dir = run_dir.join(format!("shard_{w}"));
        for e in std::fs::read_dir(&dir).unwrap() {
            let name = e.unwrap().file_name().to_string_lossy().into_owned();
            if let Some(id) =
                name.strip_prefix("client_").and_then(|s| s.strip_suffix(".state"))
            {
                let c: u64 = id.parse().unwrap();
                assert_eq!(
                    map.owner(c) as usize,
                    w,
                    "client {c}'s state landed off-owner in shard_{w}"
                );
                found += 1;
            }
        }
    }
    assert_eq!(found, 12, "every trained client must have owner-resident state");
}

#[test]
fn fa_mode_matches_parrot_semantics_but_more_trips() {
    if !artifacts_ready() {
        return;
    }
    let mut pa = base_cfg(40);
    pa.scheme = Scheme::Parrot;
    let mut fa = base_cfg(40);
    fa.scheme = Scheme::FaDist;
    let sp = run_simulation(pa).unwrap();
    let sf = run_simulation(fa).unwrap();
    // Same seed, same clients, same numerics path → same final params
    // modulo client *order* inside the weighted mean, which is
    // permutation-invariant in exact math; allow small float slack.
    let d = sp.final_params.max_abs_diff(&sf.final_params);
    assert!(d < 1e-4, "parrot vs fa params diverged: {d}");
    // FA must pay more trips (per-task messages).
    let pt = sp.metrics.total_trips();
    let ft = sf.metrics.total_trips();
    assert!(ft > pt, "fa trips {ft} !> parrot trips {pt}");
    // And more bytes (params per task).
    assert!(sf.metrics.total_bytes() > sp.metrics.total_bytes());
}

#[test]
fn uniform_vs_greedy_both_complete() {
    if !artifacts_ready() {
        return;
    }
    for (i, sched) in [SchedulerKind::Uniform, SchedulerKind::Greedy, SchedulerKind::TimeWindow(2)]
        .into_iter()
        .enumerate()
    {
        let mut cfg = base_cfg(50 + i as u64);
        cfg.scheduler = sched;
        cfg.rounds = 3;
        let s = run_simulation(cfg).unwrap();
        assert_eq!(s.metrics.rounds.len(), 3);
    }
}

#[test]
fn sp_scheme_single_device() {
    if !artifacts_ready() {
        return;
    }
    let mut cfg = base_cfg(60);
    cfg.scheme = Scheme::SP;
    cfg.n_devices = 1;
    cfg.cluster = parrot::cluster::ClusterProfile::homogeneous(1);
    let s = run_simulation(cfg).unwrap();
    assert_eq!(s.metrics.rounds.len(), 3);
    for r in &s.metrics.rounds {
        assert!(r.trips <= 2, "SP has one device: {}", r.trips);
    }
}
