//! Adversarial decode fuzzing over every coordinator message variant:
//! random truncation and byte-flip corruption of valid frames, plus
//! crafted hostile length prefixes.  Every case must come back as
//! `Ok`/`Err` — never a panic, index-out-of-bounds, or multi-GB
//! pre-allocation.  Run with `PARROT_PROP_SEED=<u64>` to replay a
//! specific sequence (scripts/ci.sh adds a random-seed pass).

use parrot::aggregation::{AggOp, ClientUpdate, DeviceAggregate, LocalAgg, Payload};
use parrot::algorithms::Broadcast;
use parrot::compress::{self, Codec};
use parrot::coordinator::messages::Msg;
use parrot::model::ParamSet;
use parrot::scheduler::TaskRecord;
use parrot::util::codec::{Decoder, Encoder};
use parrot::util::prop::{check, Gen};
use parrot::util::rng::Rng;

fn gen_params(g: &mut Gen) -> ParamSet {
    let shapes: Vec<Vec<usize>> = (0..g.int(1, 3))
        .map(|_| (0..g.int(1, 2)).map(|_| g.int(1, 10)).collect())
        .collect();
    let mut rng = Rng::new(g.rng.next_u64());
    ParamSet {
        tensors: shapes
            .iter()
            .map(|s| {
                (0..s.iter().product::<usize>().max(1))
                    .map(|_| rng.normal_f32(0.0, 2.0))
                    .collect()
            })
            .collect(),
        shapes,
    }
}

fn gen_codec(g: &mut Gen) -> Codec {
    *g.pick(&[Codec::None, Codec::Fp16, Codec::QInt8, Codec::TopK(0.3)])
}

fn gen_update(g: &mut Gen) -> ClientUpdate {
    ClientUpdate {
        client: g.int(0, 500),
        weight: g.f64(0.1, 50.0),
        entries: vec![
            ("delta".into(), AggOp::WeightedAvg, Payload::Params(gen_params(g))),
            ("h".into(), AggOp::Sum, Payload::Params(gen_params(g))),
            ("tau".into(), AggOp::Collect, Payload::Scalar(g.f64(-4.0, 4.0))),
        ],
    }
}

/// One valid frame of every message variant.
fn sample_msgs(g: &mut Gen) -> Vec<Msg> {
    let broadcast = Broadcast {
        round: g.int(0, 50),
        params: gen_params(g),
        extra: if g.bool() { Some(gen_params(g)) } else { None },
    };
    let mut la = LocalAgg::new(g.int(0, 8));
    for _ in 0..g.int(1, 4) {
        la.add(&gen_update(g));
    }
    let record = TaskRecord {
        round: g.int(0, 50),
        device: g.int(0, 8),
        n_samples: g.int(1, 400),
        secs: g.f64(0.01, 3.0),
    };
    vec![
        Msg::Round {
            round: g.int(0, 50),
            broadcast: broadcast.clone(),
            clients: (0..g.int(0, 20)).map(|_| g.int(0, 1000)).collect(),
            codec: gen_codec(g),
        },
        Msg::Task {
            round: g.int(0, 50),
            broadcast,
            client: g.int(0, 1000),
            codec: gen_codec(g),
        },
        Msg::TaskCached { round: g.int(0, 50), client: g.int(0, 1000) },
        Msg::Shutdown,
        Msg::RoundDone {
            device: g.int(0, 8),
            aggregate: la.finish(),
            records: vec![record],
            busy_secs: g.f64(0.0, 10.0),
            codec: gen_codec(g),
        },
        Msg::TaskDone {
            device: g.int(0, 8),
            update: gen_update(g),
            record,
            codec: gen_codec(g),
        },
        Msg::Idle { device: g.int(0, 8) },
        Msg::StateFetch {
            round: g.int(0, 50),
            clients: (0..g.int(0, 12)).map(|_| g.int(0, 5000) as u64).collect(),
        },
        Msg::StatePut {
            round: g.int(0, 50),
            states: (0..g.int(0, 6))
                .map(|_| {
                    let c = g.int(0, 5000) as u64;
                    if g.bool() {
                        (c, Some((0..g.int(0, 300)).map(|_| g.int(0, 255) as u8).collect()))
                    } else {
                        (c, None)
                    }
                })
                .collect(),
        },
        Msg::ShardTransfer {
            from_shard: g.int(0, 64) as u32,
            states: (0..g.int(0, 6))
                .map(|_| {
                    let c = g.int(0, 5000) as u64;
                    (c, (0..g.int(0, 300)).map(|_| g.int(0, 255) as u8).collect())
                })
                .collect(),
        },
        Msg::AsyncFlush {
            version: g.int(0, 1 << 20) as u64,
            broadcast: Broadcast {
                round: g.int(0, 50),
                params: gen_params(g),
                extra: if g.bool() { Some(gen_params(g)) } else { None },
            },
        },
        Msg::AsyncTask {
            round: g.int(0, 50),
            client: g.int(0, 1000),
            version: g.int(0, 1 << 20) as u64,
            codec: gen_codec(g),
        },
        Msg::GroupRound {
            round: g.int(0, 50),
            group: g.int(0, 32) as u32,
            broadcast: Broadcast {
                round: g.int(0, 50),
                params: gen_params(g),
                extra: if g.bool() { Some(gen_params(g)) } else { None },
            },
            clients: (0..g.int(0, 20)).map(|_| g.int(0, 1000)).collect(),
            codec: gen_codec(g),
        },
        Msg::GroupDone {
            group: g.int(0, 32) as u32,
            device: g.int(0, 8),
            aggregate: {
                let mut la = LocalAgg::new(g.int(0, 8));
                for _ in 0..g.int(1, 3) {
                    la.add(&gen_update(g));
                }
                la.finish()
            },
            records: vec![record],
            busy_secs: g.f64(0.0, 10.0),
            codec: gen_codec(g),
        },
    ]
}

#[test]
fn fuzz_truncated_frames_error_not_panic() {
    check("truncated frames", 30, |g| {
        for msg in sample_msgs(g) {
            let buf = msg.encode().unwrap();
            // The intact frame must decode.
            Msg::decode(&buf).map_err(|e| format!("valid frame rejected: {e}"))?;
            // Any prefix must fail cleanly (or trivially succeed for
            // frames whose tail is ignorable) — never panic.
            for _ in 0..8 {
                let cut = g.int(0, buf.len().saturating_sub(1));
                let _ = Msg::decode(&buf[..cut]);
            }
            // Exhaustive near the header, where counts live.
            for cut in 0..buf.len().min(64) {
                let _ = Msg::decode(&buf[..cut]);
            }
        }
        Ok(())
    });
}

#[test]
fn fuzz_bit_flipped_frames_error_not_panic() {
    check("bit-flipped frames", 30, |g| {
        for msg in sample_msgs(g) {
            let clean = msg.encode().unwrap();
            for _ in 0..6 {
                let mut buf = clean.clone();
                for _ in 0..g.int(1, 4) {
                    let i = g.int(0, buf.len() - 1);
                    buf[i] ^= 1u8 << g.int(0, 7);
                }
                let _ = Msg::decode(&buf);
            }
        }
        Ok(())
    });
}

#[test]
fn fuzz_compressed_aggregate_wire_corruption() {
    check("device aggregate corruption", 30, |g| {
        let mut la = LocalAgg::new(0);
        for _ in 0..g.int(1, 4) {
            la.add(&gen_update(g));
        }
        let agg = la.finish();
        for codec in [Codec::None, Codec::Fp16, Codec::QInt8, Codec::TopK(0.3)] {
            let clean = agg.encoded_with(codec).unwrap();
            DeviceAggregate::decode(&clean)
                .map_err(|e| format!("{codec:?}: valid aggregate rejected: {e}"))?;
            for _ in 0..6 {
                let cut = g.int(0, clean.len().saturating_sub(1));
                let _ = DeviceAggregate::decode(&clean[..cut]);
                let mut buf = clean.clone();
                let i = g.int(0, buf.len() - 1);
                buf[i] ^= 1u8 << g.int(0, 7);
                let _ = DeviceAggregate::decode(&buf);
            }
        }
        Ok(())
    });
}

#[test]
fn hostile_length_prefixes_error_before_allocating() {
    // u32::MAX counts in every container position must error fast.
    let mut enc = Encoder::new();
    enc.put_u32(u32::MAX); // ParamSet tensor count
    assert!(ParamSet::from_bytes(&enc.finish()).is_err());

    let mut enc = Encoder::new();
    enc.put_u32(3); // device
    enc.put_u32(1); // n_clients
    enc.put_u32(u32::MAX); // entry count
    assert!(DeviceAggregate::decode(&enc.finish()).is_err());

    // Msg::Round with a huge client list
    let mut enc = Encoder::new();
    enc.put_u8(0); // Round tag
    enc.put_u32(1); // round
    enc.put_u8(0); // codec none
    enc.put_u32(0); // broadcast round
    enc.put_u32(0); // empty param set
    enc.put_u8(0); // no extra
    enc.put_u32(u32::MAX); // client count
    assert!(Msg::decode(&enc.finish()).is_err());

    // RoundDone with a huge record count after a valid empty aggregate
    let agg_bytes = LocalAgg::new(0).finish().encoded().unwrap();
    let mut enc = Encoder::new();
    enc.put_u8(4); // RoundDone tag
    enc.put_u32(0); // device
    enc.put_u8(0); // codec none
    enc.put_bytes(&agg_bytes).unwrap();
    enc.put_u32(u32::MAX); // record count
    assert!(Msg::decode(&enc.finish()).is_err());

    // GroupRound with a huge client list after a valid empty broadcast
    let mut enc = Encoder::new();
    enc.put_u8(12); // GroupRound tag
    enc.put_u32(1); // round
    enc.put_u32(3); // group
    enc.put_u8(0); // codec none
    enc.put_u32(0); // broadcast round
    enc.put_u32(0); // empty param set
    enc.put_u8(0); // no extra
    enc.put_u32(u32::MAX); // client count
    assert!(Msg::decode(&enc.finish()).is_err());

    // GroupDone with a huge record count after a valid empty aggregate
    let agg_bytes = LocalAgg::new(0).finish().encoded().unwrap();
    let mut enc = Encoder::new();
    enc.put_u8(13); // GroupDone tag
    enc.put_u32(2); // group
    enc.put_u32(0); // device
    enc.put_u8(0); // codec none
    enc.put_bytes(&agg_bytes).unwrap();
    enc.put_u32(u32::MAX); // record count
    assert!(Msg::decode(&enc.finish()).is_err());

    // GroupDone whose aggregate blob length prefix overruns the frame
    let mut enc = Encoder::new();
    enc.put_u8(13);
    enc.put_u32(2);
    enc.put_u32(0);
    enc.put_u8(0);
    enc.put_u32(u32::MAX); // aggregate blob length, no payload
    assert!(Msg::decode(&enc.finish()).is_err());

    // State-store frames: huge client/state counts and a huge blob
    // length prefix must all fail the bounds check pre-allocation.
    for tag in [7u8, 8, 9] {
        let mut enc = Encoder::new();
        enc.put_u8(tag);
        enc.put_u32(0); // round / from_shard
        enc.put_u32(u32::MAX); // entry count
        assert!(Msg::decode(&enc.finish()).is_err(), "tag {tag}");
    }
    let mut enc = Encoder::new();
    enc.put_u8(9); // ShardTransfer
    enc.put_u32(0);
    enc.put_u32(1);
    enc.put_u64(1);
    enc.put_u32(u32::MAX); // blob length, no payload
    assert!(Msg::decode(&enc.finish()).is_err());

    // TopK tensor with an absurd dense length
    let mut enc = Encoder::new();
    enc.put_u8(3);
    enc.put_u32(u32::MAX);
    enc.put_u32(0);
    let buf = enc.finish();
    assert!(compress::decode_f32s(&mut Decoder::new(&buf)).is_err());
}

#[test]
fn repeated_sparse_records_cannot_amplify_allocation() {
    // A hostile frame repeating tiny top-k records with huge dense
    // lengths must hit the decoder's cumulative dense budget and error,
    // instead of amplifying a few hundred bytes into unbounded memory.
    let huge = compress::MAX_DECODE_ELEMS as u32; // 16M elements per record
    let n_records = 8; // 8 × 16M = 128M > the 64M frame budget
    let mut enc = Encoder::new();
    enc.put_u32(n_records); // ParamSet tensor count
    for _ in 0..n_records {
        enc.put_u32(1); // rank
        enc.put_u32(huge); // dim
        enc.put_u8(3); // top-k tag
        enc.put_u32(huge); // dense length (unbacked by wire bytes)
        enc.put_u32(1); // k
        enc.put_u32(0); // index
        enc.put_f32(0.0); // value
    }
    let buf = enc.finish();
    assert!(
        ParamSet::from_bytes(&buf).is_err(),
        "a ~200-byte frame must not decode into 512 MB of tensors"
    );
    // A sparse record claiming to keep zero of n>0 entries is invalid
    // (the encoder always keeps at least one).
    let mut enc = Encoder::new();
    enc.put_u8(3);
    enc.put_u32(16);
    enc.put_u32(0);
    let buf = enc.finish();
    assert!(compress::decode_f32s(&mut Decoder::new(&buf)).is_err());
}

#[test]
fn random_garbage_never_panics() {
    let mut rng = Rng::new(0xFEED_FACE);
    for _ in 0..3000 {
        let n = rng.below(200) as usize;
        let buf: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let _ = Msg::decode(&buf);
        let _ = DeviceAggregate::decode(&buf);
        let _ = ParamSet::from_bytes(&buf);
        let _ = compress::decode_f32s(&mut Decoder::new(&buf));
    }
}
