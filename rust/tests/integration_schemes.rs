//! Scheme-level integration on the virtual-time engine: the paper's
//! comparative claims as executable assertions (the same engine the
//! Fig-5..11 harnesses use, at reduced scale for test budget).

use parrot::cluster::{ClusterProfile, WorkloadCost};
use parrot::config::{Scheme, SchedulerKind};
use parrot::data::{Partition, PartitionKind};
use parrot::simulation::{run_virtual, CommModel, VRound, VirtualSim};

fn sim(
    scheme: Scheme,
    cluster: ClusterProfile,
    sched: SchedulerKind,
    partition_kind: PartitionKind,
) -> VirtualSim {
    VirtualSim::new(
        scheme,
        cluster,
        WorkloadCost::femnist(),
        CommModel::femnist(),
        sched,
        2,
        Partition::generate(partition_kind, 600, 62, 100, 21),
        1,
        9,
    )
}

fn mean_tail(rs: &[VRound]) -> f64 {
    let skip = rs.len() / 3;
    rs.iter().skip(skip).map(|r| r.total_secs).sum::<f64>() / (rs.len() - skip) as f64
}

#[test]
fn fig5_claim_parrot_fastest_scheme_on_equal_devices() {
    // On the same K devices, Parrot must beat FA (and SP trivially).
    let k = 8;
    let t = |scheme, sched| {
        let mut s = sim(scheme, ClusterProfile::homogeneous(k), sched, PartitionKind::Natural);
        mean_tail(&run_virtual(&mut s, 12, 100, 5))
    };
    let parrot = t(Scheme::Parrot, SchedulerKind::Greedy);
    let fa = t(Scheme::FaDist, SchedulerKind::Uniform);
    let sp = t(Scheme::SP, SchedulerKind::Uniform);
    assert!(parrot < fa, "parrot {parrot:.2} !< fa {fa:.2}");
    assert!(parrot < sp / 4.0, "parrot {parrot:.2} should be >> faster than SP {sp:.2}");
}

#[test]
fn fig5_claim_speedup_grows_with_heterogeneity() {
    // The 1.2-10x range: modest on homogeneous clusters, large on
    // heterogeneous ones (where FA's pull + Parrot's scheduling differ most).
    let speedup = |cluster: ClusterProfile| {
        let mut fa = sim(
            Scheme::FaDist,
            cluster.clone(),
            SchedulerKind::Uniform,
            PartitionKind::QuantitySkew(5.0),
        );
        let mut pa = sim(
            Scheme::Parrot,
            cluster,
            SchedulerKind::Greedy,
            PartitionKind::QuantitySkew(5.0),
        );
        mean_tail(&run_virtual(&mut fa, 12, 100, 5)) / mean_tail(&run_virtual(&mut pa, 12, 100, 5))
    };
    let homo = speedup(ClusterProfile::homogeneous(8));
    let hete = speedup(ClusterProfile::cluster_c(8));
    assert!(homo > 1.0, "parrot must win even homogeneous: {homo:.2}");
    assert!(hete > homo, "speedup should grow with heterogeneity: {homo:.2} -> {hete:.2}");
}

#[test]
fn table1_claim_comm_ratio_mp_over_k() {
    let mut pa = sim(
        Scheme::Parrot,
        ClusterProfile::homogeneous(8),
        SchedulerKind::Greedy,
        PartitionKind::Natural,
    );
    let mut sd = sim(
        Scheme::SdDist,
        ClusterProfile::homogeneous(8),
        SchedulerKind::Uniform,
        PartitionKind::Natural,
    );
    let pb = run_virtual(&mut pa, 2, 100, 3)[1].bytes as f64;
    let sb = run_virtual(&mut sd, 2, 100, 3)[1].bytes as f64;
    let ratio = sb / pb;
    // Mp/K = 100/8 = 12.5
    assert!((ratio - 12.5).abs() < 0.5, "comm ratio {ratio}");
}

#[test]
fn fig7_claim_near_linear_device_scaling() {
    let t = |k: usize| {
        let mut s = sim(
            Scheme::Parrot,
            ClusterProfile::homogeneous(k),
            SchedulerKind::Greedy,
            PartitionKind::Natural,
        );
        mean_tail(&run_virtual(&mut s, 12, 100, 7))
    };
    let (t4, t8, t32) = (t(4), t(8), t(32));
    assert!(t8 < t4 * 0.65, "4->8 devices: {t4:.2} -> {t8:.2}");
    assert!(t32 < t4 * 0.25, "4->32 devices: {t4:.2} -> {t32:.2}");
}

#[test]
fn fig9_claim_scheduling_absorbs_heterogeneity() {
    let t = |sched| {
        let mut s = sim(
            Scheme::Parrot,
            ClusterProfile::heterogeneous(8),
            sched,
            PartitionKind::Natural,
        );
        mean_tail(&run_virtual(&mut s, 16, 100, 9))
    };
    let with = t(SchedulerKind::Greedy);
    let without = t(SchedulerKind::Uniform);
    assert!(
        with < 0.8 * without,
        "scheduling should claw back >20% under heterogeneity: {with:.2} vs {without:.2}"
    );
}

#[test]
fn fig10_claim_benefit_holds_at_1000_concurrent() {
    let t = |sched| {
        let mut s = VirtualSim::new(
            Scheme::Parrot,
            ClusterProfile::heterogeneous(8),
            WorkloadCost::femnist(),
            CommModel::femnist(),
            sched,
            2,
            Partition::generate(PartitionKind::Natural, 5000, 62, 100, 23),
            1,
            9,
        );
        mean_tail(&run_virtual(&mut s, 8, 1000, 11))
    };
    let with = t(SchedulerKind::Greedy);
    let without = t(SchedulerKind::Uniform);
    assert!(with < without, "{with:.2} !< {without:.2}");
}

#[test]
fn utilization_high_with_scheduling() {
    let mut s = sim(
        Scheme::Parrot,
        ClusterProfile::heterogeneous(8),
        SchedulerKind::Greedy,
        PartitionKind::Natural,
    );
    let rs = run_virtual(&mut s, 12, 100, 13);
    let u: f64 =
        rs.iter().skip(4).map(|r| r.utilization()).sum::<f64>() / (rs.len() - 4) as f64;
    assert!(u > 0.85, "scheduled utilization {u:.2} should be high");
}
