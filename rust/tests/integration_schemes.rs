//! Scheme-level integration on the virtual-time engine: the paper's
//! comparative claims as executable assertions (the same engine the
//! Fig-5..11 harnesses use, at reduced scale for test budget).

use parrot::aggregation::{
    flat_aggregate, AggOp, ClientUpdate, DeviceAggregate, GlobalAgg, LocalAgg, Payload, TierAgg,
};
use parrot::cluster::{ClusterProfile, Topology, WorkloadCost};
use parrot::compress::{self, Codec};
use parrot::config::{Scheme, SchedulerKind};
use parrot::data::{Partition, PartitionKind};
use parrot::model::ParamSet;
use parrot::simulation::{
    run_virtual, AvailabilityModel, ChurnEvent, ChurnKind, ChurnSpec, CommModel, DynamicsSpec,
    SlowdownLaw, StragglerSpec, VRound, VirtualSim,
};
use parrot::util::prop::{self, Gen};
use parrot::util::rng::Rng;
use std::collections::BTreeMap;

fn sim(
    scheme: Scheme,
    cluster: ClusterProfile,
    sched: SchedulerKind,
    partition_kind: PartitionKind,
) -> VirtualSim {
    VirtualSim::new(
        scheme,
        cluster,
        WorkloadCost::femnist(),
        CommModel::femnist(),
        sched,
        2,
        Partition::generate(partition_kind, 600, 62, 100, 21),
        1,
        9,
    )
}

fn mean_tail(rs: &[VRound]) -> f64 {
    let skip = rs.len() / 3;
    rs.iter().skip(skip).map(|r| r.total_secs).sum::<f64>() / (rs.len() - skip) as f64
}

#[test]
fn fig5_claim_parrot_fastest_scheme_on_equal_devices() {
    // On the same K devices, Parrot must beat FA (and SP trivially).
    let k = 8;
    let t = |scheme, sched| {
        let mut s = sim(scheme, ClusterProfile::homogeneous(k), sched, PartitionKind::Natural);
        mean_tail(&run_virtual(&mut s, 12, 100, 5))
    };
    let parrot = t(Scheme::Parrot, SchedulerKind::Greedy);
    let fa = t(Scheme::FaDist, SchedulerKind::Uniform);
    let sp = t(Scheme::SP, SchedulerKind::Uniform);
    assert!(parrot < fa, "parrot {parrot:.2} !< fa {fa:.2}");
    assert!(parrot < sp / 4.0, "parrot {parrot:.2} should be >> faster than SP {sp:.2}");
}

#[test]
fn fig5_claim_speedup_grows_with_heterogeneity() {
    // The 1.2-10x range: modest on homogeneous clusters, large on
    // heterogeneous ones (where FA's pull + Parrot's scheduling differ most).
    let speedup = |cluster: ClusterProfile| {
        let mut fa = sim(
            Scheme::FaDist,
            cluster.clone(),
            SchedulerKind::Uniform,
            PartitionKind::QuantitySkew(5.0),
        );
        let mut pa = sim(
            Scheme::Parrot,
            cluster,
            SchedulerKind::Greedy,
            PartitionKind::QuantitySkew(5.0),
        );
        mean_tail(&run_virtual(&mut fa, 12, 100, 5)) / mean_tail(&run_virtual(&mut pa, 12, 100, 5))
    };
    let homo = speedup(ClusterProfile::homogeneous(8));
    let hete = speedup(ClusterProfile::cluster_c(8));
    assert!(homo > 1.0, "parrot must win even homogeneous: {homo:.2}");
    assert!(hete > homo, "speedup should grow with heterogeneity: {homo:.2} -> {hete:.2}");
}

#[test]
fn table1_claim_comm_ratio_mp_over_k() {
    let mut pa = sim(
        Scheme::Parrot,
        ClusterProfile::homogeneous(8),
        SchedulerKind::Greedy,
        PartitionKind::Natural,
    );
    let mut sd = sim(
        Scheme::SdDist,
        ClusterProfile::homogeneous(8),
        SchedulerKind::Uniform,
        PartitionKind::Natural,
    );
    let pb = run_virtual(&mut pa, 2, 100, 3)[1].bytes as f64;
    let sb = run_virtual(&mut sd, 2, 100, 3)[1].bytes as f64;
    let ratio = sb / pb;
    // Mp/K = 100/8 = 12.5
    assert!((ratio - 12.5).abs() < 0.5, "comm ratio {ratio}");
}

#[test]
fn fig7_claim_near_linear_device_scaling() {
    let t = |k: usize| {
        let mut s = sim(
            Scheme::Parrot,
            ClusterProfile::homogeneous(k),
            SchedulerKind::Greedy,
            PartitionKind::Natural,
        );
        mean_tail(&run_virtual(&mut s, 12, 100, 7))
    };
    let (t4, t8, t32) = (t(4), t(8), t(32));
    assert!(t8 < t4 * 0.65, "4->8 devices: {t4:.2} -> {t8:.2}");
    assert!(t32 < t4 * 0.25, "4->32 devices: {t4:.2} -> {t32:.2}");
}

#[test]
fn fig9_claim_scheduling_absorbs_heterogeneity() {
    let t = |sched| {
        let mut s = sim(
            Scheme::Parrot,
            ClusterProfile::heterogeneous(8),
            sched,
            PartitionKind::Natural,
        );
        mean_tail(&run_virtual(&mut s, 16, 100, 9))
    };
    let with = t(SchedulerKind::Greedy);
    let without = t(SchedulerKind::Uniform);
    assert!(
        with < 0.8 * without,
        "scheduling should claw back >20% under heterogeneity: {with:.2} vs {without:.2}"
    );
}

#[test]
fn fig10_claim_benefit_holds_at_1000_concurrent() {
    let t = |sched| {
        let mut s = VirtualSim::new(
            Scheme::Parrot,
            ClusterProfile::heterogeneous(8),
            WorkloadCost::femnist(),
            CommModel::femnist(),
            sched,
            2,
            Partition::generate(PartitionKind::Natural, 5000, 62, 100, 23),
            1,
            9,
        );
        mean_tail(&run_virtual(&mut s, 8, 1000, 11))
    };
    let with = t(SchedulerKind::Greedy);
    let without = t(SchedulerKind::Uniform);
    assert!(with < without, "{with:.2} !< {without:.2}");
}

#[test]
fn dynamic_sweep_at_paper_scale_completes_with_nondegenerate_utilization() {
    // The acceptance scenario: 1000 clients on 32 devices with client
    // availability < 1.0 and a scripted mid-round device departure —
    // something the pre-event-engine per-scheme loops could not even
    // represent. Every scheme must complete, and RW/SD + FA must report
    // per-executor (strictly < 1.0, scheme-distinguishing) utilization.
    let partition = Partition::generate(PartitionKind::Natural, 1000, 62, 100, 31);
    let dynamics = DynamicsSpec {
        availability: AvailabilityModel::Bernoulli(0.85),
        churn: ChurnSpec {
            events: vec![ChurnEvent { round: 2, device: 3, secs: 0.5, kind: ChurnKind::Leave }],
            leave_prob: 0.0,
            join_prob: 0.0,
        },
        straggler: StragglerSpec { prob: 0.05, law: SlowdownLaw::Fixed(3.0), drop_prob: 0.01 },
    };
    let mut utils = Vec::new();
    for (scheme, sched) in [
        (Scheme::RwDist, SchedulerKind::Uniform),
        (Scheme::FaDist, SchedulerKind::Uniform),
        (Scheme::Parrot, SchedulerKind::Greedy),
    ] {
        let mut sim = VirtualSim::new(
            scheme,
            ClusterProfile::heterogeneous(32),
            WorkloadCost::femnist(),
            CommModel::femnist(),
            sched,
            2,
            partition.clone(),
            1,
            41,
        )
        .with_dynamics(dynamics.clone());
        let rs = run_virtual(&mut sim, 6, 100, 19);
        assert_eq!(rs.len(), 6);
        let departures: usize = rs.iter().map(|r| r.departures).sum();
        assert!(departures >= 1, "{scheme:?}: scripted departure must fire");
        let unavailable: usize = rs.iter().map(|r| r.unavailable_clients).sum();
        assert!(unavailable > 0, "{scheme:?}: Bernoulli(0.85) must filter clients");
        for r in &rs {
            assert!(r.total_secs.is_finite() && r.total_secs > 0.0, "{scheme:?}: {r:?}");
        }
        let u = rs.iter().map(|r| r.utilization()).sum::<f64>() / rs.len() as f64;
        assert!(u > 0.0 && u < 1.0, "{scheme:?}: utilization {u} must be non-degenerate");
        utils.push((scheme, u));
    }
    // The schemes' utilizations must actually distinguish them (the old
    // RW/SD accounting pinned utilization at exactly 1.0 for any input).
    let (rw, fa) = (utils[0].1, utils[1].1);
    assert!((rw - fa).abs() > 1e-3, "RW/SD {rw} vs FA {fa} should differ");
    assert!(utils.iter().all(|&(_, u)| u < 0.999));
}

// ---------------------------------------------------------------
// Depth-invariance property harness: hierarchical aggregation over a
// *random tree* (depth 1–4, uneven fan-out, empty branches allowed)
// equals flat aggregation for every AggOp × codec, with a wire
// encode/decode at every tier boundary.  Runs under the printed
// PARROT_PROP_SEED (scripts/ci.sh replays the suite on a random seed).

fn prop_params(rng: &mut Rng, shapes: &[Vec<usize>]) -> ParamSet {
    let tensors = shapes
        .iter()
        .map(|s| {
            (0..s.iter().product::<usize>().max(1))
                .map(|_| rng.normal_f32(0.0, 1.0))
                .collect()
        })
        .collect();
    ParamSet { shapes: shapes.to_vec(), tensors }
}

fn prop_update(rng: &mut Rng, client: usize, shapes: &[Vec<usize>]) -> ClientUpdate {
    ClientUpdate {
        client,
        weight: rng.range_f64(1.0, 100.0),
        entries: vec![
            ("delta".into(), AggOp::WeightedAvg, Payload::Params(prop_params(rng, shapes))),
            ("delta_c".into(), AggOp::Avg, Payload::Params(prop_params(rng, shapes))),
            ("h".into(), AggOp::Sum, Payload::Params(prop_params(rng, shapes))),
            ("snap".into(), AggOp::Collect, Payload::Params(prop_params(rng, shapes))),
            ("tau".into(), AggOp::Collect, Payload::Scalar(rng.next_f64())),
            ("gsq".into(), AggOp::Sum, Payload::Scalar(rng.next_f64())),
        ],
    }
}

/// Aggregate `idxs` through a random tree of `depth` remaining tier
/// levels; every child is serialized with `codec` before merging into
/// its parent (exactly what the wire does), and each encode's
/// reconstruction bound accumulates into `bounds`.
fn tier_aggregate(
    g: &mut Gen,
    updates: &[ClientUpdate],
    idxs: &[usize],
    depth: usize,
    codec: Codec,
    bounds: &mut BTreeMap<String, f64>,
    next_id: &mut usize,
) -> DeviceAggregate {
    let id = *next_id;
    *next_id += 1;
    if depth == 0 || idxs.len() <= 1 {
        let mut local = LocalAgg::new(id);
        for &i in idxs {
            local.add(&updates[i]);
        }
        return local.finish();
    }
    // Uneven fan-out: each update lands in a uniformly random child —
    // some children may stay empty (an aggregator with no clients).
    let fan = g.int(1, 4);
    let mut chunks: Vec<Vec<usize>> = vec![Vec::new(); fan];
    for &i in idxs {
        let c = g.int(0, fan - 1);
        chunks[c].push(i);
    }
    let mut tier = TierAgg::new(id);
    for ch in chunks {
        let child = tier_aggregate(g, updates, &ch, depth - 1, codec, bounds, next_id);
        for (name, b) in child.reconstruction_bounds(codec) {
            *bounds.entry(name).or_insert(0.0) += b;
        }
        let wire = child.encoded_with(codec).unwrap();
        tier.merge(DeviceAggregate::decode(&wire).expect("tier wire round trip"));
    }
    tier.finish()
}

#[test]
fn prop_depth_invariance_tree_aggregation_equals_flat() {
    // The §4.2 guarantee lifted to arbitrary-depth topologies: a tree
    // of TierAggs (groups-of-groups, uneven fan-out) must reproduce
    // flat aggregation within the codec's accumulated analytic bound,
    // and Collect ("Special Params") entries must survive every tier
    // verbatim.
    for codec in [Codec::None, Codec::Fp16, Codec::QInt8, Codec::TopK(0.4)] {
        prop::check(&format!("depth invariance under {}", codec.name()), 20, |g| {
            let shapes = vec![vec![g.int(1, 6), g.int(1, 6)], vec![g.int(1, 12)]];
            let m = g.int(1, 24);
            let depth = g.int(1, 4);
            let mut rng = Rng::new(g.rng.next_u64());
            let updates: Vec<ClientUpdate> =
                (0..m).map(|c| prop_update(&mut rng, c, &shapes)).collect();
            let flat = flat_aggregate(&updates);
            let total_weight: f64 = updates.iter().map(|u| u.weight).sum();

            let mut bounds: BTreeMap<String, f64> = BTreeMap::new();
            let mut next_id = 0usize;
            let idxs: Vec<usize> = (0..m).collect();
            let root =
                tier_aggregate(g, &updates, &idxs, depth, codec, &mut bounds, &mut next_id);
            // The server's final merge consumes the root's wire form
            // too — one more encode, one more bound contribution.
            for (name, b) in root.reconstruction_bounds(codec) {
                *bounds.entry(name).or_insert(0.0) += b;
            }
            let wire = root.encoded_with(codec).unwrap();
            let mut global = GlobalAgg::new();
            global.merge(DeviceAggregate::decode(&wire).map_err(|e| e.to_string())?);
            let hier = global.finish();

            if hier.n_clients != m {
                return Err(format!("client count {} != {m}", hier.n_clients));
            }
            // f32 reassociation slack: sums add in tree order, not flat
            // order; deeper trees reassociate more.
            let slack = 1e-3;
            let checks = [
                ("delta", bounds.get("delta").copied().unwrap_or(0.0) / total_weight),
                ("delta_c", bounds.get("delta_c").copied().unwrap_or(0.0) / m as f64),
                ("h", bounds.get("h").copied().unwrap_or(0.0)),
            ];
            for (name, tol) in checks {
                let d = flat.params[name].max_abs_diff(&hier.params[name]) as f64;
                if d > tol + slack {
                    return Err(format!(
                        "{} depth={depth} m={m}: {name} diff {d} > bound {tol} + {slack}",
                        codec.name()
                    ));
                }
            }
            if (flat.scalars["gsq"] - hier.scalars["gsq"]).abs() > 1e-9 {
                return Err("gsq sum drifted through the tiers".into());
            }
            // Collect survives every tier verbatim, any depth.
            for coll in ["tau", "snap"] {
                let mut f: Vec<&(usize, Payload)> = flat.collected[coll].iter().collect();
                let mut h: Vec<&(usize, Payload)> = hier.collected[coll].iter().collect();
                f.sort_by_key(|x| x.0);
                h.sort_by_key(|x| x.0);
                if f.len() != h.len() {
                    return Err(format!("{coll}: collected count mismatch"));
                }
                for (a, b) in f.iter().zip(&h) {
                    if a.0 != b.0 {
                        return Err(format!("{coll}: client set mismatch"));
                    }
                    let exact = match (&a.1, &b.1) {
                        (Payload::Params(p), Payload::Params(q)) => p.max_abs_diff(q) == 0.0,
                        (x, y) => x == y,
                    };
                    if !exact {
                        return Err(format!(
                            "{}: {coll} not forwarded verbatim at depth {depth}",
                            codec.name()
                        ));
                    }
                }
            }
            Ok(())
        });
    }
}

#[test]
fn grouped_topology_engine_matches_flat_aggregation_semantics() {
    // The engine-side acceptance shape at test scale: a grouped
    // topology must strictly shrink cross-WAN bytes vs flat on the
    // identical stream at (near-)equal makespan, with the group
    // structure visible in the new VRound columns.
    let partition = Partition::generate(PartitionKind::Natural, 300, 62, 100, 21);
    let run = |topology: Topology| {
        let mut sim = VirtualSim::new(
            Scheme::Parrot,
            ClusterProfile::heterogeneous(8).with_topology(topology),
            WorkloadCost::femnist(),
            CommModel::femnist(),
            SchedulerKind::Greedy,
            2,
            partition.clone(),
            1,
            9,
        );
        run_virtual(&mut sim, 6, 64, 5)
    };
    let flat = run(Topology::flat());
    let grouped = run(Topology::groups(4));
    let total = |rs: &[VRound]| rs.iter().map(|r| r.total_secs).sum::<f64>();
    let cross = |rs: &[VRound]| rs.iter().map(|r| r.cross_group_bytes).sum::<u64>();
    assert!(
        cross(&grouped) < cross(&flat),
        "grouping must shrink cross-WAN bytes: {} !< {}",
        cross(&grouped),
        cross(&flat)
    );
    assert!(
        total(&grouped) <= total(&flat) * 1.15 + 1.0,
        "grouped makespan {:.2} vs flat {:.2}",
        total(&grouped),
        total(&flat)
    );
    for r in &grouped {
        assert_eq!(r.group_aggs, 4, "round {}: all four groups must report", r.round);
        assert!(r.cross_group_bytes < r.bytes, "round {}: some legs are LAN", r.round);
    }
    for r in &flat {
        assert_eq!(r.group_aggs, 8, "flat: one aggregate per device");
        assert_eq!(r.cross_group_bytes, r.bytes, "flat: every leg is WAN");
    }
    // Same number of clients trained either way.
    let done = |rs: &[VRound]| rs.iter().map(|r| r.scheduled_clients).sum::<usize>();
    assert_eq!(done(&flat), done(&grouped));
}

#[test]
fn compression_engine_bytes_equal_encoded_sizes() {
    // The acceptance invariant: the engine's comm-byte columns book the
    // codec's *encoded* upload size, not raw f32 — and that booked size
    // is the measured truth: a real n-param tensor encodes to exactly
    // `wire_bytes(n)` payload bytes + the fixed 5-byte tag+length
    // envelope.
    let n_params = 50_000usize;
    let k = 8usize;
    let mut rng = Rng::new(5);
    let tensor: Vec<f32> = (0..n_params).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    for codec in [Codec::None, Codec::Fp16, Codec::QInt8, Codec::TopK(0.1)] {
        // measured encoding == the size the engine books (+5 envelope)
        let wire = codec.wire_bytes(n_params);
        assert_eq!(compress::encoded_len(&tensor, codec), wire + 5, "{codec:?}");

        let comm = CommModel {
            s_a: (n_params * 4) as u64,
            s_e: 0,
            codec,
        };
        let mut sim = VirtualSim::new(
            Scheme::Parrot,
            ClusterProfile::homogeneous(k),
            WorkloadCost::femnist(),
            comm,
            SchedulerKind::Greedy,
            2,
            Partition::generate(PartitionKind::Natural, 300, 62, 100, 21),
            1,
            9,
        );
        let rs = run_virtual(&mut sim, 1, 50, 3);
        let r = &rs[0];
        // K raw broadcasts down + K encoded uploads up, nothing else.
        assert_eq!(
            r.bytes,
            (n_params as u64 * 4 + wire as u64) * k as u64,
            "{codec:?}: engine bytes must equal encoded sizes"
        );
        assert_eq!(r.trips, 2 * k as u64);
    }
}

#[test]
fn compression_shrinks_device_aggregate_3_5x() {
    // Acceptance: QInt8 and TopK(0.1) shrink the measured encoded
    // DeviceAggregate for a synthetic model ≥ 3.5× vs raw f32.
    let shapes = vec![vec![256, 128], vec![128], vec![128, 62], vec![62]];
    let mut rng = Rng::new(11);
    let mut la = LocalAgg::new(0);
    for c in 0..4 {
        let tensors: Vec<Vec<f32>> = shapes
            .iter()
            .map(|s| {
                (0..s.iter().product::<usize>())
                    .map(|_| rng.normal_f32(0.0, 1.0))
                    .collect()
            })
            .collect();
        la.add(&ClientUpdate {
            client: c,
            weight: 1.0 + c as f64,
            entries: vec![(
                "delta".into(),
                AggOp::WeightedAvg,
                Payload::Params(ParamSet { shapes: shapes.clone(), tensors }),
            )],
        });
    }
    let agg = la.finish();
    let raw = agg.size_bytes_with(Codec::None) as f64;
    for codec in [Codec::QInt8, Codec::TopK(0.1)] {
        let enc = agg.size_bytes_with(codec) as f64;
        assert!(
            raw / enc >= 3.5,
            "{codec:?}: ratio {:.2} < 3.5 ({raw} -> {enc})",
            raw / enc
        );
    }
    let fp16 = agg.size_bytes_with(Codec::Fp16) as f64;
    assert!(raw / fp16 > 1.9, "fp16 ratio {:.2}", raw / fp16);
}

#[test]
fn utilization_high_with_scheduling() {
    let mut s = sim(
        Scheme::Parrot,
        ClusterProfile::heterogeneous(8),
        SchedulerKind::Greedy,
        PartitionKind::Natural,
    );
    let rs = run_virtual(&mut s, 12, 100, 13);
    let u: f64 =
        rs.iter().skip(4).map(|r| r.utilization()).sum::<f64>() / (rs.len() - 4) as f64;
    assert!(u > 0.85, "scheduled utilization {u:.2} should be high");
}
