//! Double-run determinism differential (README "Determinism
//! discipline"): the engine halves of the `--smoke` experiment drivers
//! must produce byte-identical summary rows when run twice in the same
//! process under the same seed.  This is the dynamic complement to the
//! static `parrot lint` pass — a stray HashMap iteration, ambient
//! clock, or order-sensitive float fold anywhere under these drivers
//! shows up here as a row diff.
//!
//! Seeded like the prop/fuzz suites: `PARROT_PROP_SEED=<u64>` (decimal
//! or 0x-hex), defaulting to the fixed CI seed.  Failures print the
//! seed for replay.

use anyhow::Result;
use parrot::exp::{asyncscale, dynamics, toposcale};

/// Same contract as the (private) master seed in `util::prop`:
/// `PARROT_PROP_SEED` as decimal or 0x-hex, default 0xC0FF_EE00.
fn seed() -> u64 {
    match std::env::var("PARROT_PROP_SEED") {
        Ok(s) => {
            let s = s.trim();
            let parsed = match s.strip_prefix("0x") {
                Some(h) => u64::from_str_radix(h, 16).ok(),
                None => s.parse().ok(),
            };
            parsed.unwrap_or_else(|| {
                panic!("PARROT_PROP_SEED must be a u64 (decimal or 0x-hex), got {s:?}")
            })
        }
        Err(_) => 0xC0FF_EE00,
    }
}

fn assert_identical(name: &str, s: u64, a: &[String], b: &[String]) {
    assert_eq!(
        a, b,
        "{name} rows diverged across two identical runs — nondeterminism in the \
         engine path (replay with PARROT_PROP_SEED={s:#x})"
    );
    assert!(!a.is_empty(), "{name} produced no rows (PARROT_PROP_SEED={s:#x})");
}

#[test]
fn dynamics_rows_are_run_invariant() {
    let s = seed();
    println!("dynamics double-run under PARROT_PROP_SEED={s:#x}");
    let a = dynamics::smoke_rows(s);
    let b = dynamics::smoke_rows(s);
    assert_identical("dynamics", s, &a, &b);
}

#[test]
fn asyncscale_rows_are_run_invariant() -> Result<()> {
    let s = seed();
    println!("asyncscale double-run under PARROT_PROP_SEED={s:#x}");
    let a = asyncscale::smoke_rows(s, 60, 5)?;
    let b = asyncscale::smoke_rows(s, 60, 5)?;
    assert_identical("asyncscale", s, &a, &b);
    Ok(())
}

#[test]
fn toposcale_rows_are_run_invariant() -> Result<()> {
    let s = seed();
    println!("toposcale double-run under PARROT_PROP_SEED={s:#x}");
    let a = toposcale::smoke_rows(s)?;
    let b = toposcale::smoke_rows(s)?;
    assert_identical("toposcale", s, &a, &b);
    Ok(())
}
