//! Determinism differentials (README "Determinism discipline"): the
//! engine halves of the `--smoke` experiment drivers must produce
//! byte-identical summary rows
//!
//!   * when run twice in the same process under the same seed
//!     (double-run differential), and
//!   * for ANY `--threads N` — the headline invariant of the
//!     group-sharded engine: `--threads 1`, `2` and `8` must yield the
//!     same rows byte-for-byte (thread differential).  Threads only
//!     size the worker pool; the shard decomposition, per-shard RNG
//!     streams and merge order are fixed by the topology and seed.
//!
//! This is the dynamic complement to the static `parrot lint` pass — a
//! stray HashMap iteration, ambient clock, order-sensitive float fold,
//! or any cross-shard leak anywhere under these drivers shows up here
//! as a row diff.
//!
//! Seeded like the prop/fuzz suites: `PARROT_PROP_SEED=<u64>` (decimal
//! or 0x-hex), defaulting to the fixed CI seed.  Failures print the
//! seed for replay.

use anyhow::Result;
use parrot::exp::{asyncscale, dynamics, megascale, parscale, toposcale};

/// Same contract as the (private) master seed in `util::prop`:
/// `PARROT_PROP_SEED` as decimal or 0x-hex, default 0xC0FF_EE00.
fn seed() -> u64 {
    match std::env::var("PARROT_PROP_SEED") {
        Ok(s) => {
            let s = s.trim();
            let parsed = match s.strip_prefix("0x") {
                Some(h) => u64::from_str_radix(h, 16).ok(),
                None => s.parse().ok(),
            };
            parsed.unwrap_or_else(|| {
                panic!("PARROT_PROP_SEED must be a u64 (decimal or 0x-hex), got {s:?}")
            })
        }
        Err(_) => 0xC0FF_EE00,
    }
}

fn assert_identical(name: &str, s: u64, a: &[String], b: &[String]) {
    assert_eq!(
        a, b,
        "{name} rows diverged across two identical runs — nondeterminism in the \
         engine path (replay with PARROT_PROP_SEED={s:#x})"
    );
    assert!(!a.is_empty(), "{name} produced no rows (PARROT_PROP_SEED={s:#x})");
}

/// The thread-differential assertion: `rows_at[0]` is the
/// single-threaded reference, the rest came from larger worker pools.
fn assert_thread_invariant(name: &str, s: u64, rows_at: &[(usize, Vec<String>)]) {
    let (_, reference) = &rows_at[0];
    assert!(!reference.is_empty(), "{name} produced no rows (PARROT_PROP_SEED={s:#x})");
    for (threads, rows) in &rows_at[1..] {
        assert_eq!(
            reference, rows,
            "{name} rows diverged between --threads {} and --threads {threads} — \
             the sharded engine leaked thread-count dependence \
             (replay with PARROT_PROP_SEED={s:#x})",
            rows_at[0].0
        );
    }
}

#[test]
fn dynamics_rows_are_run_invariant() {
    let s = seed();
    println!("dynamics double-run under PARROT_PROP_SEED={s:#x}");
    let a = dynamics::smoke_rows(s, 1);
    let b = dynamics::smoke_rows(s, 1);
    assert_identical("dynamics", s, &a, &b);
}

#[test]
fn asyncscale_rows_are_run_invariant() -> Result<()> {
    let s = seed();
    println!("asyncscale double-run under PARROT_PROP_SEED={s:#x}");
    let a = asyncscale::smoke_rows(s, 60, 5, 1)?;
    let b = asyncscale::smoke_rows(s, 60, 5, 1)?;
    assert_identical("asyncscale", s, &a, &b);
    Ok(())
}

#[test]
fn toposcale_rows_are_run_invariant() -> Result<()> {
    let s = seed();
    println!("toposcale double-run under PARROT_PROP_SEED={s:#x}");
    let a = toposcale::smoke_rows(s, 1)?;
    let b = toposcale::smoke_rows(s, 1)?;
    assert_identical("toposcale", s, &a, &b);
    Ok(())
}

#[test]
fn dynamics_rows_are_thread_invariant() {
    let s = seed();
    println!("dynamics 1-vs-2-vs-8-thread differential under PARROT_PROP_SEED={s:#x}");
    let rows_at: Vec<(usize, Vec<String>)> =
        [1, 2, 8].map(|t| (t, dynamics::smoke_rows(s, t))).into_iter().collect();
    assert_thread_invariant("dynamics", s, &rows_at);
}

#[test]
fn asyncscale_rows_are_thread_invariant() -> Result<()> {
    let s = seed();
    println!("asyncscale 1-vs-2-vs-8-thread differential under PARROT_PROP_SEED={s:#x}");
    let mut rows_at = Vec::new();
    for t in [1, 2, 8] {
        rows_at.push((t, asyncscale::smoke_rows(s, 60, 5, t)?));
    }
    assert_thread_invariant("asyncscale", s, &rows_at);
    Ok(())
}

#[test]
fn toposcale_rows_are_thread_invariant() -> Result<()> {
    let s = seed();
    println!("toposcale 1-vs-2-vs-8-thread differential under PARROT_PROP_SEED={s:#x}");
    let mut rows_at = Vec::new();
    for t in [1, 2, 8] {
        rows_at.push((t, toposcale::smoke_rows(s, t)?));
    }
    assert_thread_invariant("toposcale", s, &rows_at);
    Ok(())
}

/// The megascale pin (tentpole): at 100k clients the SoA-table engine's
/// per-round rows — including the deterministic heap-pop count column —
/// must be byte-identical for `--threads` 1, 2 and 8 on one seed.  The
/// batch-admission and index-range shard views must not perturb the
/// `(time bits, namespaced seq)` merge law at any worker-pool size.
#[test]
fn megascale_rows_are_thread_invariant() -> Result<()> {
    let s = seed();
    println!("megascale 1-vs-2-vs-8-thread differential under PARROT_PROP_SEED={s:#x}");
    let mut rows_at = Vec::new();
    for t in [1, 2, 8] {
        rows_at.push((t, megascale::smoke_rows(s, t)?));
    }
    assert_thread_invariant("megascale", s, &rows_at);
    Ok(())
}

/// Double-run differential on the same cell: the arena-batched event
/// path must be a pure function of the seed within one process too.
#[test]
fn megascale_rows_are_run_invariant() -> Result<()> {
    let s = seed();
    println!("megascale double-run under PARROT_PROP_SEED={s:#x}");
    let a = megascale::smoke_rows(s, 2)?;
    let b = megascale::smoke_rows(s, 2)?;
    assert_identical("megascale", s, &a, &b);
    Ok(())
}

/// The megascale trace differential: the rendered Chrome trace bytes of
/// the traced 100k-client cell must be byte-identical across two runs
/// (the arena columns must not leak allocation order into the trace).
#[test]
fn megascale_trace_bytes_are_run_invariant() -> Result<()> {
    let s = seed();
    println!("megascale trace double-run under PARROT_PROP_SEED={s:#x}");
    let a = megascale::smoke_trace(s, 2)?;
    let b = megascale::smoke_trace(s, 2)?;
    assert_eq!(
        a, b,
        "megascale trace bytes diverged across two identical runs \
         (replay with PARROT_PROP_SEED={s:#x})"
    );
    Ok(())
}

/// The trace differential: the rendered Chrome trace-event file of a
/// grouped (always-sharded) traced cell must be byte-identical for
/// `--threads` 1, 2 and 8 on one seed.  `smoke_trace` also runs
/// `chrome::check_well_formed` internally (balanced B/E pairs, per-
/// track monotone timestamps), so a pass here certifies structure too.
#[test]
fn chrome_trace_bytes_are_thread_invariant() -> Result<()> {
    let s = seed();
    println!("trace-export 1-vs-2-vs-8-thread differential under PARROT_PROP_SEED={s:#x}");
    let reference = parscale::smoke_trace(s, 1)?;
    assert!(
        reference.starts_with("{\"traceEvents\":["),
        "trace export is not Chrome trace-event JSON (PARROT_PROP_SEED={s:#x})"
    );
    assert!(
        reference.contains("\"sim.bytes\""),
        "trace export lost its metrics registry (PARROT_PROP_SEED={s:#x})"
    );
    for t in [2usize, 8] {
        let other = parscale::smoke_trace(s, t)?;
        assert_eq!(
            reference, other,
            "exported trace bytes diverged between --threads 1 and --threads {t} — \
             the tracer leaked thread-count dependence \
             (replay with PARROT_PROP_SEED={s:#x})"
        );
    }
    Ok(())
}

/// Double-run: tracing itself must be a pure function of the seed.
#[test]
fn chrome_trace_bytes_are_run_invariant() -> Result<()> {
    let s = seed();
    println!("trace-export double-run under PARROT_PROP_SEED={s:#x}");
    let a = parscale::smoke_trace(s, 2)?;
    let b = parscale::smoke_trace(s, 2)?;
    assert_eq!(
        a, b,
        "exported trace bytes diverged across two identical runs \
         (replay with PARROT_PROP_SEED={s:#x})"
    );
    Ok(())
}
