//! End-to-end AOT bridge test: replay the numeric test vectors dumped by
//! `python/compile/aot.py` through the Rust PJRT runtime and compare.
//!
//! This is the single test that pins cross-language numerics: jax
//! computed outputs at build time; the exact same HLO executed from Rust
//! must reproduce them.  Requires `make artifacts` (skips cleanly if the
//! artifacts are absent).

use parrot::model::{Dtype, ParamSet, Role};
use parrot::runtime::{lit_f32, lit_i32, lit_scalar, Runtime};
use std::path::{Path, PathBuf};

fn artifact_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

struct TestVec {
    entries: Vec<(String, String, String, usize, Vec<usize>)>, // io, name, dtype, size, shape
    blob: Vec<u8>,
}

impl TestVec {
    fn load(name: &str) -> Option<TestVec> {
        let idx = artifact_dir().join(format!("testvec_{name}.idx"));
        let bin = artifact_dir().join(format!("testvec_{name}.bin"));
        if !idx.exists() || !bin.exists() {
            return None;
        }
        let entries = std::fs::read_to_string(idx)
            .unwrap()
            .lines()
            .map(|l| {
                let p: Vec<&str> = l.split_whitespace().collect();
                let shape = if p[4] == "-" {
                    vec![]
                } else {
                    p[4].split(',').map(|d| d.parse().unwrap()).collect()
                };
                (
                    p[0].to_string(),
                    p[1].to_string(),
                    p[2].to_string(),
                    p[3].parse().unwrap(),
                    shape,
                )
            })
            .collect();
        Some(TestVec { entries, blob: std::fs::read(bin).unwrap() })
    }

    /// Cut the blob into per-entry raw byte slices.
    fn slices(&self) -> Vec<(&(String, String, String, usize, Vec<usize>), &[u8])> {
        let mut out = Vec::new();
        let mut off = 0;
        for e in &self.entries {
            let n = 4 * e.3;
            out.push((e, &self.blob[off..off + n]));
            off += n;
        }
        assert_eq!(off, self.blob.len(), "testvec blob size mismatch");
        out
    }
}

fn as_f32(raw: &[u8]) -> Vec<f32> {
    raw.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

fn as_i32(raw: &[u8]) -> Vec<i32> {
    raw.chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

fn allclose(name: &str, got: &[f32], want: &[f32], rtol: f32, atol: f32) {
    assert_eq!(got.len(), want.len(), "{name}: length");
    let mut worst = 0.0f32;
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        let tol = atol + rtol * w.abs();
        let d = (g - w).abs();
        if d > tol {
            panic!("{name}[{i}]: got {g}, want {w} (|diff|={d} > tol={tol})");
        }
        worst = worst.max(d);
    }
}

/// Replay one artifact's testvec through PJRT.
fn replay(name: &str, rtol: f32) {
    let Some(tv) = TestVec::load(name) else {
        eprintln!("skipping {name}: artifacts not built");
        return;
    };
    let rt = Runtime::cpu(artifact_dir()).expect("pjrt cpu client");
    let exe = rt.load(name).expect("load artifact");
    let slices = tv.slices();
    let n_in = exe.manifest.inputs.len();
    assert_eq!(
        slices.iter().filter(|(e, _)| e.0 == "in").count(),
        n_in,
        "{name}: input count"
    );

    let mut inputs = Vec::with_capacity(n_in);
    for ((_, nm, dt, _, shape), raw) in slices.iter().take(n_in) {
        let lit = match dt.as_str() {
            "f32" => {
                if shape.is_empty() {
                    lit_scalar(as_f32(raw)[0])
                } else {
                    lit_f32(&as_f32(raw), shape).unwrap()
                }
            }
            "i32" => lit_i32(&as_i32(raw), shape).unwrap(),
            _ => panic!("dtype {dt} in testvec entry {nm}"),
        };
        inputs.push(lit);
    }
    let outs = exe.execute(&inputs).expect("execute");
    for (lit, ((_, nm, _, _, _), raw)) in outs.iter().zip(slices[n_in..].iter()) {
        let got = lit.to_vec::<f32>().expect("output to_vec");
        allclose(&format!("{name}.{nm}"), &got, &as_f32(raw), rtol, 1e-5);
    }
}

#[test]
fn mlp_eval_matches_jax() {
    replay("mlp_eval", 1e-4);
}

#[test]
fn mlp_grad_matches_jax() {
    replay("mlp_grad", 1e-3);
}

#[test]
fn mlp_train_matches_jax() {
    replay("mlp_train", 1e-3);
}

#[test]
fn task_run_multi_step_changes_params() {
    // Beyond the single-step replay: drive several batches through
    // TaskRun and check params move + loss is finite.
    let dir = artifact_dir();
    if !dir.join("mlp_train.manifest.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use parrot::data::{FederatedDataset, Partition, PartitionKind, SynthConfig};
    let rt = Runtime::cpu(&dir).unwrap();
    let exe = rt.load("mlp_train").unwrap();
    let shapes = exe.manifest.param_shapes();
    let params = ParamSet::init_he(&shapes, 1);
    let zeros = ParamSet::zeros(&shapes);
    let ds = FederatedDataset::new(
        SynthConfig::vision(3),
        Partition::generate(PartitionKind::Natural, 4, 62, 80, 3),
    );
    let mut run = exe.start_task(&params, &zeros, &zeros, 0.05, 0.0).unwrap();
    let mut losses = Vec::new();
    for j in 0..4 {
        let (loss, gsq) = run.step(&ds.batch(0, j % ds.n_batches(0))).unwrap();
        assert!(loss.is_finite() && gsq >= 0.0);
        losses.push(loss);
    }
    let new_params = run.finish().unwrap();
    assert!(new_params.max_abs_diff(&params) > 0.0, "params must move");
    // Same-batch repetition should trend the loss down on this easy task.
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "losses: {losses:?}"
    );
}

#[test]
fn manifest_consistency_across_artifacts() {
    let dir = artifact_dir();
    if !dir.join("mlp_train.manifest.txt").exists() {
        return;
    }
    for model in parrot::model::MODEL_NAMES {
        let rt_manifests: Vec<_> = parrot::model::STEP_KINDS
            .iter()
            .map(|k| {
                parrot::model::Manifest::load(
                    dir.join(format!("{model}_{k}.manifest.txt")),
                )
                .unwrap()
            })
            .collect();
        // All step kinds of one model agree on the parameter layout.
        let shapes: Vec<_> = rt_manifests.iter().map(|m| m.param_shapes()).collect();
        assert_eq!(shapes[0], shapes[1]);
        assert_eq!(shapes[0], shapes[2]);
        // Roles are well-formed.
        for m in &rt_manifests {
            assert!(m.inputs.iter().all(|d| d.role != Role::Metric));
            assert!(m
                .outputs
                .iter()
                .all(|d| d.role == Role::Param || d.role == Role::Metric));
            assert!(m.inputs.iter().any(|d| d.dtype == Dtype::I32)); // y
        }
    }
}
