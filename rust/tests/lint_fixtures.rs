//! Fixture self-test for the `parrot lint` analyzer: the miniature
//! repo tree under `rust/tests/fixtures/lint_tree/` plants exactly one
//! instance of every violation class, and the whole pipeline must
//!
//!   (a) fire all eleven registered rules with zero unresolved call
//!       sites,
//!   (b) reproduce the golden JSON-lines report (blessed on first run,
//!       like golden_traces.rs — scripts/ci.sh runs the suite twice per
//!       invocation, so a fresh snapshot is verified in the same run),
//!   (c) emit lines that parse back through `util::json::parse`.
//!
//! This is the static backing for the ci.sh gate's "fails on injected
//! violations" guarantee: if a rule rots, the fixture count drifts and
//! this suite — not a production incident — reports it.

use std::collections::BTreeMap;
use std::path::PathBuf;

fn fixture_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust")
        .join("tests")
        .join("fixtures")
        .join("lint_tree")
}

fn analyze() -> parrot::analysis::Analysis {
    parrot::analysis::run(&fixture_root()).expect("analyze fixture tree")
}

fn render(findings: &[parrot::analysis::rules::Finding]) -> String {
    findings
        .iter()
        .map(|f| format!("  {}:{} {}: {}", f.file, f.line, f.rule, f.message))
        .collect::<Vec<_>>()
        .join("\n")
}

#[test]
fn every_rule_fires_and_every_call_resolves() {
    let analysis = analyze();
    assert!(
        analysis.unresolved.is_empty(),
        "the fixture tree must resolve every call site: {:?}",
        analysis.unresolved
    );
    let mut by_rule: BTreeMap<&str, usize> = BTreeMap::new();
    for f in &analysis.findings {
        *by_rule.entry(f.rule).or_insert(0) += 1;
    }
    let want: BTreeMap<&str, usize> = [
        ("unordered-iter", 4),
        ("unordered-iter-transitive", 1),
        ("ambient-entropy", 1),
        ("ambient-entropy-transitive", 1),
        ("panicking-decode", 1),
        ("panicking-decode-transitive", 1),
        ("unchecked-narrow", 2),
        ("float-order", 1),
        ("wire-asymmetry", 2),
        ("unguarded-len-alloc", 1),
        ("unfuzzed-variant", 1),
    ]
    .into_iter()
    .collect();
    assert_eq!(
        by_rule,
        want,
        "per-rule finding counts drifted on the fixture tree:\n{}",
        render(&analysis.findings)
    );
    for r in parrot::analysis::rules::RULES {
        assert!(by_rule.contains_key(r.name), "registered rule `{}` never fired", r.name);
    }

    // Anchor spot-checks: the messages carry the interesting payloads.
    let msg_of = |rule: &str| {
        analysis
            .findings
            .iter()
            .find(|f| f.rule == rule)
            .unwrap_or_else(|| panic!("{rule} finding present"))
            .message
            .clone()
    };
    let chain = msg_of("ambient-entropy-transitive");
    assert!(chain.contains("`crate::util::helpers::stamp`"), "{chain}");
    assert!(chain.contains("`crate::util::timer::wall_secs`"), "{chain}");
    assert!(chain.contains("util/timer.rs"), "two-hop witness chain: {chain}");
    let tagged = analysis
        .findings
        .iter()
        .find(|f| f.rule == "wire-asymmetry" && f.file == "coordinator/messages.rs")
        .expect("Msg arm-level asymmetry finding");
    assert!(tagged.message.contains("tag 0 (Ping)"), "{}", tagged.message);
    let swapped = analysis
        .findings
        .iter()
        .find(|f| f.rule == "wire-asymmetry" && f.file == "compress/mod.rs")
        .expect("generic order-swap asymmetry finding");
    assert!(swapped.message.contains("[u32 f32]"), "{}", swapped.message);
    assert!(msg_of("unfuzzed-variant").contains("`Msg::Stop`"));
}

#[test]
fn fixture_report_matches_golden_snapshot() {
    let analysis = analyze();
    let lines: Vec<String> =
        analysis.findings.iter().map(|f| parrot::analysis::to_json_line(f, false)).collect();
    assert!(!lines.is_empty(), "fixture tree produced no findings");
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust")
        .join("tests")
        .join("golden")
        .join("lint_fixtures.jsonl");
    if !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).expect("create golden dir");
        let mut body = lines.join("\n");
        body.push('\n');
        std::fs::write(&path, body).expect("write golden snapshot");
        eprintln!(
            "lint_fixtures: blessed new snapshot {} ({} lines) — commit it",
            path.display(),
            lines.len()
        );
        return;
    }
    let want_body = std::fs::read_to_string(&path).expect("read golden snapshot");
    let want: Vec<&str> = want_body.lines().filter(|l| !l.trim().is_empty()).collect();
    assert_eq!(
        want.len(),
        lines.len(),
        "snapshot has {} lines, run produced {} — analyzer output drifted \
         (delete the snapshot to re-pin deliberately)",
        want.len(),
        lines.len()
    );
    for (i, (w, g)) in want.iter().zip(&lines).enumerate() {
        assert_eq!(
            *w,
            g.as_str(),
            "lint_fixtures.jsonl line {i} drifted (delete rust/tests/golden/\
             lint_fixtures.jsonl to re-pin deliberately)"
        );
    }
}

#[test]
fn every_emitted_line_parses_through_util_json() {
    let analysis = analyze();
    for (i, f) in analysis.findings.iter().enumerate() {
        let line = parrot::analysis::to_json_line(f, i % 2 == 0);
        let v = parrot::util::json::parse(&line)
            .unwrap_or_else(|e| panic!("line {i} is not valid JSON ({e}): {line}"));
        assert_eq!(v.render(), line, "parse -> render must round-trip line {i}");
    }
}
