//! Golden-trace regression suite: pins the summary tables of
//! `parrot exp dynamics --smoke` and `parrot exp asyncscale --smoke`
//! (fixed seeds, virtual-time-only columns) against committed
//! snapshots, so engine/scheduler refactors cannot silently change the
//! timelines.
//!
//! Comparison rules: integer columns must match exactly; float columns
//! are tolerance-banded (relative 1e-6) to absorb innocuous
//! cross-platform fp noise while still catching real drift; everything
//! else is compared as a string.
//!
//! Snapshots are *blessed on first run*: if `rust/tests/golden/<name>`
//! is missing, the test writes the freshly computed table there and
//! passes (scripts/ci.sh runs the test suite twice per invocation, so
//! a blessed snapshot is verified within the same CI run).  To
//! intentionally re-pin after a behavior change, delete the snapshot
//! file and re-run `cargo test --test golden_traces`, then commit the
//! regenerated file with the change that moved it.

use std::path::PathBuf;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust")
        .join("tests")
        .join("golden")
}

/// Compare one CSV field under the integer-exact / float-banded rules.
fn field_matches(want: &str, got: &str) -> Result<(), String> {
    if want == got {
        return Ok(());
    }
    if let (Ok(a), Ok(b)) = (want.parse::<i64>(), got.parse::<i64>()) {
        if a == b {
            return Ok(());
        }
        return Err(format!("integer column {a} != {b}"));
    }
    if let (Ok(a), Ok(b)) = (want.parse::<f64>(), got.parse::<f64>()) {
        let tol = 1e-6 * a.abs().max(1.0);
        if (a - b).abs() <= tol {
            return Ok(());
        }
        return Err(format!("float column {b} outside {a} ± {tol}"));
    }
    Err(format!("column {want:?} != {got:?}"))
}

fn check_golden(name: &str, rows: &[String]) {
    let path = golden_dir().join(name);
    if !path.exists() {
        std::fs::create_dir_all(golden_dir()).expect("create golden dir");
        let mut body = rows.join("\n");
        body.push('\n');
        std::fs::write(&path, body).expect("write golden snapshot");
        eprintln!(
            "golden_traces: blessed new snapshot {} ({} rows) — commit it",
            path.display(),
            rows.len()
        );
        return;
    }
    let want_body = std::fs::read_to_string(&path).expect("read golden snapshot");
    let want: Vec<&str> = want_body.lines().filter(|l| !l.trim().is_empty()).collect();
    assert_eq!(
        want.len(),
        rows.len(),
        "{name}: snapshot has {} rows, run produced {} — timelines drifted \
         (delete the snapshot to re-pin deliberately)",
        want.len(),
        rows.len()
    );
    for (i, (w, g)) in want.iter().zip(rows).enumerate() {
        let wf: Vec<&str> = w.split(',').collect();
        let gf: Vec<&str> = g.split(',').collect();
        assert_eq!(
            wf.len(),
            gf.len(),
            "{name} row {i}: column count changed\n  snapshot: {w}\n  run:      {g}"
        );
        for (j, (a, b)) in wf.iter().zip(&gf).enumerate() {
            if let Err(e) = field_matches(a, b) {
                panic!(
                    "{name} row {i} col {j}: {e}\n  snapshot: {w}\n  run:      {g}\n\
                     (engine/scheduler timeline drifted; delete \
                     rust/tests/golden/{name} to re-pin deliberately)"
                );
            }
        }
    }
}

#[test]
fn golden_dynamics_smoke_table() {
    // Fixed seed 51 — the `exp dynamics --smoke` default.
    let rows = parrot::exp::dynamics::smoke_rows(51, 1);
    assert_eq!(rows.len(), 15, "3 schemes x 5 scenarios");
    check_golden("dynamics_smoke.csv", &rows);
}

#[test]
fn golden_megascale_smoke_table() {
    // Fixed seed 47 — the `exp megascale` default.  Pins the SoA-table
    // engine's 100k-client rows (virtual-time/byte columns plus the
    // deterministic heap-pop count) against a committed snapshot.
    let rows = parrot::exp::megascale::smoke_rows(47, 2)
        .expect("megascale smoke cell must produce rows");
    assert_eq!(rows.len(), 2, "two rounds of the smoke cell");
    check_golden("megascale_smoke.csv", &rows);
}

#[test]
fn golden_asyncscale_smoke_table() {
    // Fixed seed 19 — the `exp asyncscale --smoke` default.  smoke_rows
    // also re-runs the ledger differential and the degenerate sync pin.
    let rows = parrot::exp::asyncscale::smoke_rows(19, 60, 5, 1)
        .expect("asyncscale smoke differential must hold");
    assert_eq!(rows.len(), 3, "sync / degenerate / buffered rows");
    check_golden("asyncscale_smoke.csv", &rows);
}
