//! Fixture: the wire-path violations — an order-swapped codec pair,
//! an unchecked length cast, an unguarded length allocation, and
//! decode paths that panic (directly and through a helper).

/// Writes x (u32) then y (f32)...
pub fn encode_point(enc: &mut Encoder, x: u32, y: f32) {
    enc.put_u32(x);
    enc.put_f32(y);
}

/// ...while the reader takes y first: `wire-asymmetry`.
pub fn decode_point(dec: &mut Decoder) -> (u32, f32) {
    let y = dec.f32();
    let x = dec.u32();
    (x, y)
}

/// Length prefix narrowed with a bare cast: `unchecked-narrow`.
pub fn encode_table(enc: &mut Encoder, xs: &[u64]) {
    enc.put_u32(xs.len() as u32);
    for &x in xs {
        enc.put_u64(x);
    }
}

/// Wire-symmetric with `encode_table`, but the count drives
/// `Vec::with_capacity` before any bound: `unguarded-len-alloc`.
pub fn decode_table(dec: &mut Decoder) -> Vec<u64> {
    let n = dec.u32() as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(dec.u64());
    }
    out
}

/// Panics on hostile input: `panicking-decode`.
pub fn decode_tag(dec: &mut Decoder) -> u8 {
    let b = dec.u8();
    if b > 3 {
        panic!("bad tag {b}")
    }
    b
}

/// Not decode-named, so the direct rule is blind to it; seeds
/// PANICKING for the transitive pass.
fn check_tag(b: u8) -> u8 {
    if b > 3 {
        panic!("tag out of range")
    }
    b
}

/// Calls the panicking helper from a decode path:
/// `panicking-decode-transitive`.
pub fn decode_guarded(dec: &mut Decoder) -> u8 {
    check_tag(dec.u8())
}
