//! Fixture: order-dependent float accumulation over an unordered
//! source — `float-order`, plus the strict-module Hash* mentions.

use std::collections::HashMap;

/// Sums f64 weights straight out of a HashMap's value iterator.
pub fn merge(weights: &HashMap<u64, f64>) -> f64 {
    weights.values().map(|w| *w).sum::<f64>()
}
