//! Fixture: the strict engine module — direct Hash*/entropy findings
//! plus the two transitive boundary crossings into util helpers.

use std::collections::HashSet;

/// Direct strict-module Hash* use: `unordered-iter` fires twice, on
/// the `use` above and on the binding below.
pub fn dedupe(xs: &[u64]) -> usize {
    let mut seen: HashSet<u64> = HashSet::new();
    let mut n = 0;
    for &x in xs {
        if seen.insert(x) {
            n += 1;
        }
    }
    n
}

/// Direct ambient entropy in a strict module: `ambient-entropy`.
pub fn jitter_seed() -> u64 {
    let t = std::time::Instant::now();
    let _ = t;
    7
}

/// Strict module calling a util helper that holds a HashMap:
/// `unordered-iter-transitive` fires on the call line.
pub fn round_cost(xs: &[u64]) -> usize {
    crate::util::helpers::tally(xs)
}

/// Strict module reaching the clock through two hops:
/// `ambient-entropy-transitive` with the full witness chain.
pub fn round_started_at() -> f64 {
    crate::util::helpers::stamp()
}

pub struct EngineCfg {
    pub state_bytes: u64,
}

/// Config-sourced narrowing in a strict module: `unchecked-narrow`
/// (the cfg-cast extension) fires on the cast below.
pub fn blob_bytes(cfg: &EngineCfg) -> usize {
    cfg.state_bytes as usize
}
