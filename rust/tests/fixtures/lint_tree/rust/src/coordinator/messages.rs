//! Fixture: the framed control protocol — one variant's field width
//! mismatches between the encode and decode arms (`wire-asymmetry` at
//! tag level) and one variant is missing from the fuzz sample pool
//! (`unfuzzed-variant`).

pub enum Msg {
    Ping { seq: u64 },
    Stop,
}

impl Msg {
    pub fn encode(&self) -> Vec<u8> {
        let mut enc = Encoder::new();
        match self {
            Msg::Ping { seq } => {
                enc.put_u8(0);
                enc.put_u32(*seq as u32);
            }
            Msg::Stop => enc.put_u8(1),
        }
        enc.finish()
    }

    pub fn decode(buf: &[u8]) -> Msg {
        let mut dec = Decoder::new(buf);
        let tag = dec.u8();
        match tag {
            0 => Msg::Ping { seq: dec.u64() },
            _ => Msg::Stop,
        }
    }
}
