//! Fixture: the allowlisted clock.  Ambient entropy is legal in this
//! file (and util/bench.rs) per the `ambient-entropy` policy, but it
//! still seeds the effect bit that `ambient-entropy-transitive`
//! propagates up to strict-module callers.

/// Seconds of real time — entropy-allowlisted, effect-seeding.
pub fn wall_secs() -> f64 {
    let t = std::time::SystemTime::now();
    let _ = t;
    0.0
}
