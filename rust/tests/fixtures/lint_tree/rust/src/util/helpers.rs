//! Fixture: non-strict helpers that launder effects — a Hash* tally
//! and a wallclock stamp.  Neither is a direct finding here (util is
//! not determinism-critical); both must be caught at the strict-module
//! call sites by effect propagation.

use std::collections::HashMap;

/// Holds a HashMap: seeds HOLDS_HASH for the transitive pass.
pub fn tally(xs: &[u64]) -> usize {
    let mut m: HashMap<u64, u64> = HashMap::new();
    for &x in xs {
        *m.entry(x).or_insert(0) += 1;
    }
    m.len()
}

/// One hop from the clock: AMBIENT_ENTROPY arrives transitively.
pub fn stamp() -> f64 {
    crate::util::timer::wall_secs()
}
