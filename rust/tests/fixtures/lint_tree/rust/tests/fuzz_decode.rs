//! Fixture fuzz pool: one variant is deliberately absent from
//! `sample_msgs`, so the analyzer must report it as `unfuzzed-variant`.

pub fn sample_msgs() -> Vec<Msg> {
    vec![Msg::Ping { seq: 7 }]
}
