//! System-level property tests on the coordinator invariants (the
//! in-tree prop harness standing in for proptest — DESIGN.md §6):
//! message-codec round trips, schedule partitioning under arbitrary
//! loads, hierarchical==flat aggregation through the *wire* encoding,
//! and state-manager durability under arbitrary interleavings.

// The shadow model below deliberately uses a HashMap: the property
// is that the store matches it regardless of iteration order.
#![allow(clippy::disallowed_types)]

use parrot::aggregation::{AggOp, ClientUpdate, DeviceAggregate, GlobalAgg, LocalAgg, Payload};
use parrot::compress::Codec;
use parrot::config::SchedulerKind;
use parrot::coordinator::messages::Msg;
use parrot::model::ParamSet;
use parrot::scheduler::{Scheduler, TaskRecord};
use parrot::state::StateManager;
use parrot::util::prop::{check, Gen};
use parrot::util::rng::Rng;

fn gen_params(g: &mut Gen) -> ParamSet {
    let shapes: Vec<Vec<usize>> = (0..g.int(1, 4))
        .map(|_| (0..g.int(1, 3)).map(|_| g.int(1, 12)).collect())
        .collect();
    let mut rng = Rng::new(g.rng.next_u64());
    ParamSet {
        tensors: shapes
            .iter()
            .map(|s| {
                (0..s.iter().product::<usize>().max(1))
                    .map(|_| rng.normal_f32(0.0, 2.0))
                    .collect()
            })
            .collect(),
        shapes,
    }
}

#[test]
fn prop_message_codec_round_trip() {
    check("msg codec", 60, |g| {
        let params = gen_params(g);
        let clients: Vec<usize> = (0..g.int(0, 40)).map(|_| g.int(0, 5000)).collect();
        let msg = Msg::Round {
            round: g.int(0, 10_000),
            broadcast: parrot::algorithms::Broadcast {
                round: 0,
                params: params.clone(),
                extra: if g.bool() { Some(params.clone()) } else { None },
            },
            clients: clients.clone(),
            codec: *g.pick(&[Codec::None, Codec::Fp16, Codec::QInt8, Codec::TopK(0.5)]),
        };
        match Msg::decode(&msg.encode().unwrap()) {
            Ok(Msg::Round { clients: c2, broadcast, .. }) => {
                if c2 != clients {
                    return Err("clients mutated".into());
                }
                if broadcast.params.max_abs_diff(&params) != 0.0 {
                    return Err("params mutated".into());
                }
                Ok(())
            }
            Ok(_) => Err("wrong variant".into()),
            Err(e) => Err(format!("decode failed: {e}")),
        }
    });
}

#[test]
fn prop_schedule_partitions_any_round() {
    check("schedule partition", 60, |g| {
        let k = g.int(1, 16);
        let mut sched = Scheduler::new(
            *g.pick(&[
                SchedulerKind::Uniform,
                SchedulerKind::Greedy,
                SchedulerKind::TimeWindow(3),
            ]),
            g.int(0, 3),
            k,
        );
        // arbitrary history
        for _ in 0..g.int(0, 50) {
            sched.record(TaskRecord {
                round: g.int(0, 10),
                device: g.int(0, k - 1),
                n_samples: g.int(1, 500),
                secs: g.f64(0.01, 5.0),
            });
        }
        let m = g.int(0, 80);
        let clients: Vec<(usize, usize)> = (0..m).map(|i| (i, g.int(2, 400))).collect();
        let round = g.int(0, 12);
        let s = sched.schedule(round, &clients);
        if s.assignment.len() != k {
            return Err(format!("{} device lists != {k}", s.assignment.len()));
        }
        let mut seen: Vec<usize> = s.assignment.iter().flatten().cloned().collect();
        seen.sort_unstable();
        if seen != (0..m).collect::<Vec<_>>() {
            return Err(format!("partition broken: {} of {m}", seen.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_hierarchical_equals_flat_through_wire() {
    // Same invariant as the unit test but through the full Msg encode /
    // decode path the real coordinator uses.
    check("hier == flat via wire", 30, |g| {
        let shapes = vec![vec![g.int(1, 10)], vec![g.int(1, 6), g.int(1, 6)]];
        let mut rng = Rng::new(g.rng.next_u64());
        let m = g.int(1, 24);
        let k = g.int(1, 5);
        let updates: Vec<ClientUpdate> = (0..m)
            .map(|c| ClientUpdate {
                client: c,
                weight: rng.range_f64(1.0, 50.0),
                entries: vec![(
                    "delta".into(),
                    AggOp::WeightedAvg,
                    Payload::Params(ParamSet {
                        shapes: shapes.clone(),
                        tensors: shapes
                            .iter()
                            .map(|s| {
                                (0..s.iter().product::<usize>())
                                    .map(|_| rng.normal_f32(0.0, 1.0))
                                    .collect()
                            })
                            .collect(),
                    }),
                )],
            })
            .collect();
        let flat = parrot::aggregation::flat_aggregate(&updates);
        let mut global = GlobalAgg::new();
        for dev in 0..k {
            let mut la = LocalAgg::new(dev);
            for (i, u) in updates.iter().enumerate() {
                if i % k == dev {
                    la.add(u);
                }
            }
            // ship through the actual message type
            let msg = Msg::RoundDone {
                device: dev,
                aggregate: la.finish(),
                records: vec![],
                busy_secs: 0.0,
                codec: Codec::None,
            };
            match Msg::decode(&msg.encode().unwrap()) {
                Ok(Msg::RoundDone { aggregate, .. }) => global.merge(aggregate),
                _ => return Err("wire round trip failed".into()),
            }
        }
        let hier = global.finish();
        let d = flat.params["delta"].max_abs_diff(&hier.params["delta"]);
        if d < 1e-4 {
            Ok(())
        } else {
            Err(format!("hier vs flat diff {d}"))
        }
    });
}

#[test]
fn prop_device_aggregate_wire_stable() {
    check("device agg wire", 40, |g| {
        let mut la = LocalAgg::new(g.int(0, 30));
        let n = g.int(1, 10);
        for c in 0..n {
            la.add(&ClientUpdate {
                client: c,
                weight: g.f64(0.1, 10.0),
                entries: vec![
                    ("p".into(), AggOp::WeightedAvg, Payload::Params(gen_params(g))),
                    ("s".into(), AggOp::Sum, Payload::Scalar(g.f64(-5.0, 5.0))),
                    ("c".into(), AggOp::Collect, Payload::Scalar(g.f64(0.0, 9.0))),
                ],
            });
        }
        let agg = la.finish();
        let wire = agg.encoded().unwrap();
        let back = DeviceAggregate::decode(&wire).map_err(|e| e.to_string())?;
        if back.encoded().unwrap() != wire {
            return Err("re-encode differs".into());
        }
        if back.n_clients != n {
            return Err("client count mutated".into());
        }
        Ok(())
    });
}

#[test]
fn prop_state_manager_durable_any_interleaving() {
    let dir = std::env::temp_dir().join(format!("parrot_prop_state_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut sm = StateManager::new(&dir, 4096).unwrap();
    let mut shadow: std::collections::HashMap<u64, Vec<u8>> = Default::default();
    check("state durability", 200, |g| {
        let client = g.int(0, 30) as u64;
        if g.bool() {
            let val: Vec<u8> = (0..g.int(0, 600)).map(|_| g.int(0, 255) as u8).collect();
            sm.save(client, &val).map_err(|e| e.to_string())?;
            shadow.insert(client, val);
        } else {
            let got = sm.load(client).map_err(|e| e.to_string())?;
            if got.as_deref() != shadow.get(&client).map(|v| v.as_slice()) {
                return Err(format!("client {client}: stored/loaded mismatch"));
            }
        }
        Ok(())
    });
    let _ = std::fs::remove_dir_all(&dir);
}
