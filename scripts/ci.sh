#!/usr/bin/env bash
# One-command quality gate: formatting, lints, build, tests.
#
#   ./scripts/ci.sh            # everything
#   ./scripts/ci.sh --fast     # skip the release build (debug test run only)
#
# Later PRs should keep this green; it is what "tier-1" means for this
# repo plus the style gates (rustfmt, clippy -D warnings).

set -euo pipefail
cd "$(dirname "$0")/.."

FAST=0
[ "${1:-}" = "--fast" ] && FAST=1

# Master seed for every randomized pass below (property/fuzz re-runs
# and the experiment smokes); printed so any failure is replayable.
# Full-width u64: four 15-bit $RANDOM draws spread across the word.
# The seed is passed through UNMODIFIED everywhere below — truncating
# it (the old `% 100000`) made the printed repro seed differ from the
# seed actually run, and collapsed the explored space to 10^5 values.
SEED="${PARROT_PROP_SEED:-$(( (RANDOM << 45) ^ (RANDOM << 30) ^ (RANDOM << 15) ^ RANDOM ))}"

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets -- -D warnings

if [ "$FAST" -eq 0 ]; then
  echo "==> cargo build --release"
  cargo build --release

  # Benches only compile when invoked by hand and can rot silently;
  # --no-run keeps them building without paying for a measurement run.
  echo "==> cargo bench --no-run"
  cargo bench --no-run
fi

# Determinism & wire-safety static analysis (rust/src/analysis): the
# committed lint.baseline is a one-way ratchet — new findings fail the
# gate, fixed findings must be re-baselined with --write-baseline.
if [ "$FAST" -eq 0 ]; then
  echo "==> parrot lint --format json (baseline ratchet)"
  LINT_OUT="$(mktemp)"
  if ! target/release/parrot lint --format json --out "$LINT_OUT"; then
    echo "ci.sh: parrot lint found new violations — run 'target/release/parrot lint'" >&2
    echo "ci.sh: for the human-readable report; fix them (do not grow lint.baseline)." >&2
    echo "ci.sh: JSON-lines report archived at $LINT_OUT" >&2
    exit 1
  fi
  rm -f "$LINT_OUT"
  # The ratchet is fully paid down: the committed baseline must stay
  # comment-only.  The binary already validates rule names in entries;
  # this guards against re-grandfathering findings instead of fixing
  # them.
  if grep -Evq '^[[:space:]]*(#|$)' lint.baseline; then
    echo "ci.sh: lint.baseline has non-comment entries — the ratchet is one-way:" >&2
    grep -Ev '^[[:space:]]*(#|$)' lint.baseline >&2
    echo "ci.sh: fix the findings instead of re-grandfathering them." >&2
    exit 1
  fi
fi

echo "==> cargo test -q  (property/fuzz suites run on their fixed default seed)"
cargo test -q

# Golden-trace snapshots: a first run blesses missing snapshots, so a
# second pass in the same CI invocation genuinely verifies them.
echo "==> cargo test -q --test golden_traces (verify committed/blessed snapshots)"
cargo test -q --test golden_traces
# Lint fixture self-test: the analyzer must fire all eleven rules on
# the injected-violation tree and match its golden JSON-lines report
# (same bless-then-verify contract as golden_traces above).
echo "==> cargo test -q --test lint_fixtures (analyzer fixture self-test)"
cargo test -q --test lint_fixtures
# Freshly blessed snapshots only protect future runs once committed.
if command -v git >/dev/null 2>&1; then
  UNTRACKED_GOLDEN="$(git ls-files --others --exclude-standard rust/tests/golden 2>/dev/null || true)"
  if [ -n "$UNTRACKED_GOLDEN" ]; then
    echo "ci.sh: NOTE — newly blessed golden snapshots are uncommitted:" >&2
    echo "$UNTRACKED_GOLDEN" >&2
    echo "ci.sh: commit them so timeline drift is caught across revisions." >&2
  fi
fi

# Second property/fuzz pass on a fresh random master seed, so the
# suites keep exploring new cases run-to-run.  On failure the seed is
# printed for exact reproduction (the prop harness also prints it in
# the panic message).
echo "==> property/fuzz re-run with PARROT_PROP_SEED=$SEED"
if ! PARROT_PROP_SEED="$SEED" cargo test -q --test prop_coordinator --test prop_statestore \
    --test fuzz_decode \
  || ! PARROT_PROP_SEED="$SEED" cargo test -q --lib prop_; then
  echo "ci.sh: property/fuzz failure — reproduce with PARROT_PROP_SEED=$SEED" >&2
  exit 1
fi

# Distributed-state smoke: a small sharded write-back run (50 clients,
# 2 shards) whose engine-booked state bytes must equal the store's
# counters, plus the sim-vs-deploy differential (the same access
# sequence through the virtual SimStore and real StateManagers must
# agree on every shared counter).
if [ "$FAST" -eq 0 ]; then
  echo "==> parrot exp statescale --smoke (seed $SEED)"
  SMOKE_RESULTS="$(mktemp -d)"
  if ! target/release/parrot exp statescale --smoke --shards 2 \
      --seed "$SEED" --results "$SMOKE_RESULTS"; then
    echo "ci.sh: statescale smoke failure — reproduce with --seed $SEED" >&2
    exit 1
  fi
  rm -rf "$SMOKE_RESULTS"
fi

# Async-buffered smoke: the virtual dispatcher's flush counters must be
# reproduced by the deploy-side FlushLedger replaying the identical
# arrival sequence, and the degenerate (buffer = M_p, max-staleness 0)
# configuration must match the sync Parrot timeline exactly.
if [ "$FAST" -eq 0 ]; then
  echo "==> parrot exp asyncscale --smoke (seed $SEED)"
  SMOKE_RESULTS="$(mktemp -d)"
  if ! target/release/parrot exp asyncscale --smoke \
      --seed "$SEED" --results "$SMOKE_RESULTS"; then
    echo "ci.sh: asyncscale smoke failure — reproduce with --seed $SEED" >&2
    exit 1
  fi
  rm -rf "$SMOKE_RESULTS"
fi

# Topology smoke: the engine must shrink cross-WAN bytes with grouping
# at (near-)equal makespan, and the deploy-side LocalAgg -> TierAgg ->
# GlobalAgg pipeline (wire round trips at every tier, per codec) must
# match flat aggregation and the engine's group-aggregate structure at
# 1000 clients.
if [ "$FAST" -eq 0 ]; then
  echo "==> parrot exp toposcale --smoke (seed $SEED)"
  SMOKE_RESULTS="$(mktemp -d)"
  if ! target/release/parrot exp toposcale --smoke \
      --seed "$SEED" --results "$SMOKE_RESULTS"; then
    echo "ci.sh: toposcale smoke failure — reproduce with --seed $SEED" >&2
    exit 1
  fi
  rm -rf "$SMOKE_RESULTS"
fi

# Parallel-engine thread differential: the 1-vs-2-vs-8 row comparison
# in the determinism suite, then the parscale smoke (flat + groups:16
# at --threads {1,2}) which re-asserts byte-identical rows in-process
# and reports the engine wall-clock per thread count.
echo "==> cargo test -q --test determinism (thread differential, seed $SEED)"
if ! PARROT_PROP_SEED="$SEED" cargo test -q --test determinism; then
  echo "ci.sh: determinism failure — reproduce with PARROT_PROP_SEED=$SEED" >&2
  exit 1
fi
if [ "$FAST" -eq 0 ]; then
  echo "==> parrot exp parscale --smoke --trace (seed $SEED)"
  SMOKE_RESULTS="$(mktemp -d)"
  TRACE_FILE="$SMOKE_RESULTS/trace.json"
  if ! target/release/parrot exp parscale --smoke \
      --seed "$SEED" --results "$SMOKE_RESULTS" --trace "$TRACE_FILE"; then
    echo "ci.sh: parscale smoke failure — reproduce with --seed $SEED" >&2
    exit 1
  fi
  # Observability smoke: the exported Chrome trace must exist, be
  # non-empty, and open with the trace-event envelope (the determinism
  # suite above already asserted the bytes are thread-invariant and
  # well-formed; this checks the --trace plumbing end to end).
  if [ ! -s "$TRACE_FILE" ]; then
    echo "ci.sh: --trace produced no/empty file — reproduce with --seed $SEED" >&2
    exit 1
  fi
  case "$(head -c 16 "$TRACE_FILE")" in
    '{"traceEvents":['*) ;;
    *)
      echo "ci.sh: --trace output is not Chrome trace-event JSON — reproduce with --seed $SEED" >&2
      exit 1
      ;;
  esac
  rm -rf "$SMOKE_RESULTS"
fi

# Megascale smoke: the SoA-table engine at 100k clients — per-round
# rows (including the deterministic heap-pop count) must be
# byte-identical across --threads {1,2,8}; events/sec and peak RSS are
# reported into BENCH_megascale.json.
if [ "$FAST" -eq 0 ]; then
  echo "==> parrot exp megascale --smoke (seed $SEED)"
  SMOKE_RESULTS="$(mktemp -d)"
  if ! target/release/parrot exp megascale --smoke \
      --seed "$SEED" --results "$SMOKE_RESULTS"; then
    echo "ci.sh: megascale smoke failure — reproduce with --seed $SEED" >&2
    exit 1
  fi
  rm -rf "$SMOKE_RESULTS"
fi

echo "ci.sh: all green"
