#!/usr/bin/env bash
# One-command quality gate: formatting, lints, build, tests.
#
#   ./scripts/ci.sh            # everything
#   ./scripts/ci.sh --fast     # skip the release build (debug test run only)
#
# Later PRs should keep this green; it is what "tier-1" means for this
# repo plus the style gates (rustfmt, clippy -D warnings).

set -euo pipefail
cd "$(dirname "$0")/.."

FAST=0
[ "${1:-}" = "--fast" ] && FAST=1

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets -- -D warnings

if [ "$FAST" -eq 0 ]; then
  echo "==> cargo build --release"
  cargo build --release
fi

echo "==> cargo test -q"
cargo test -q

echo "ci.sh: all green"
