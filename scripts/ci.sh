#!/usr/bin/env bash
# One-command quality gate: formatting, lints, build, tests.
#
#   ./scripts/ci.sh            # everything
#   ./scripts/ci.sh --fast     # skip the release build (debug test run only)
#
# Later PRs should keep this green; it is what "tier-1" means for this
# repo plus the style gates (rustfmt, clippy -D warnings).

set -euo pipefail
cd "$(dirname "$0")/.."

FAST=0
[ "${1:-}" = "--fast" ] && FAST=1

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets -- -D warnings

if [ "$FAST" -eq 0 ]; then
  echo "==> cargo build --release"
  cargo build --release
fi

echo "==> cargo test -q  (property/fuzz suites run on their fixed default seed)"
cargo test -q

# Second property/fuzz pass on a fresh random master seed, so the
# suites keep exploring new cases run-to-run.  On failure the seed is
# printed for exact reproduction (the prop harness also prints it in
# the panic message).
SEED="${PARROT_PROP_SEED:-$((RANDOM * 32768 + RANDOM))}"
echo "==> property/fuzz re-run with PARROT_PROP_SEED=$SEED"
if ! PARROT_PROP_SEED="$SEED" cargo test -q --test prop_coordinator --test fuzz_decode \
  || ! PARROT_PROP_SEED="$SEED" cargo test -q --lib prop_; then
  echo "ci.sh: property/fuzz failure — reproduce with PARROT_PROP_SEED=$SEED" >&2
  exit 1
fi

echo "ci.sh: all green"
